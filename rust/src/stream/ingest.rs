//! `emproc ingest` — watermark-triggered incremental pipelines over a
//! live observation feed (DESIGN.md §15).
//!
//! Observations arrive one line at a time ([`super::FeedEvent`]), are
//! bucketed into fixed event-time windows, and per-source watermarks
//! (`max t seen − allowed lateness`; an ended source's watermark is
//! `+∞`) decide when a window is complete. Windows close strictly in
//! order; closing window `k` sweeps its buffered observations into the
//! accumulated per-`(source, aircraft)` sets and re-runs the *batch*
//! stage runners over exactly what the window touched:
//!
//! 1. **organize** — full-file overwrite of each touched
//!    `organized/<tier>/<icao>_<src>.csv` from the accumulated set,
//!    sorted by feed sequence number (raw row order — byte-identical to
//!    what batch stage 1 writes once the feed drains);
//! 2. **archive** — re-pack each touched bottom directory with the
//!    stage-2 task runner ([`crate::archive::zipdir::archive_dir`] /
//!    [`crate::archive::columnar::archive_dir_columnar`]);
//! 3. **process** — re-run [`crate::workflow::stage3::process_archive`]
//!    on each repacked archive with one persistent PJRT model.
//!
//! Every step is a full overwrite from accumulated state, so closing a
//! window is idempotent; the PR 5 journal records window `k` *after*
//! its refresh lands, which makes `--resume` after `kill -9` skip
//! exactly the windows whose effects are already on disk and replay the
//! rest. Late and duplicate observations are counted and diverted to
//! `rejected.log`, never into the data plane. Each observation carries
//! its arrival [`Instant`]; when its window's refresh completes the
//! elapsed time becomes one observation→processed-row latency sample
//! ([`IngestReport::latency`]).

use super::{FeedEvent, FeedObs, FEED_VERSION};
use crate::archive::ArchiveFormat;
use crate::cli::ArgParser;
use crate::metrics::Percentiles;
use crate::recovery::{journal_path, load_verified, JournalEvent, JournalPlan, JournalWriter};
use crate::registry::Registry;
use crate::tracks::{icao24_hex, Observation, SegmentConfig, Track};
use anyhow::{bail, Context as _, Result};
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::io::{BufRead, Write as _};
use std::path::PathBuf;
use std::time::Instant;

/// Journal capacity in windows (task ids are window indices; the plan
/// is sized up front because the feed's extent is unknown).
pub const MAX_WINDOWS: usize = 1 << 20;

/// Everything `emproc ingest` needs.
#[derive(Debug, Clone)]
pub struct IngestConfig {
    /// Feed file to read (`-` means stdin at the CLI).
    pub feed: PathBuf,
    /// Run directory: `organized/`, `archived/`, `processed/`,
    /// `journal/` and `rejected.log` all live here.
    pub out_dir: PathBuf,
    /// Event-time window width, seconds.
    pub window_s: i64,
    /// Allowed lateness, seconds: a source's watermark trails its
    /// newest observation by this much. Must cover twice the replayer's
    /// `--disorder` or shifted stragglers get rejected as late.
    pub lateness_s: i64,
    /// Archive format for the incremental stage-2 refreshes.
    pub format: ArchiveFormat,
    /// Hierarchy year for organized paths (batch stage 1 pins 2019).
    pub year: u16,
    /// AOT model artifacts for the stage-3 refreshes.
    pub artifact_dir: PathBuf,
    /// Resume from `journal/ingest.emproc`: verified completed windows
    /// sweep their buffers but skip the (already landed) refresh.
    pub resume: bool,
}

impl IngestConfig {
    /// Defaults matching the batch pipeline: 600 s windows, 60 s
    /// lateness, zip archives, year 2019, default artifact dir.
    pub fn new(feed: PathBuf, out_dir: PathBuf) -> Self {
        IngestConfig {
            feed,
            out_dir,
            window_s: 600,
            lateness_s: 60,
            format: ArchiveFormat::Zip,
            year: 2019,
            artifact_dir: crate::runtime::TrackModel::default_dir(),
            resume: false,
        }
    }
}

/// What one ingest run saw and did.
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// Observations accepted into windows.
    pub observations: u64,
    /// Windows closed (in order, empty ones included).
    pub windows_closed: u64,
    /// Subset of closed windows whose refresh was skipped because the
    /// resume journal already recorded them.
    pub windows_skipped: u64,
    /// Observations rejected as late (their window had already closed).
    pub late: u64,
    /// Observations rejected as duplicates of an already-seen
    /// `(source, aircraft, seq)`.
    pub duplicates: u64,
    /// Observations dropped because the aircraft is not in the feed's
    /// registry (batch stage 1 skips these too).
    pub unregistered: u64,
    /// Observation→processed-row latency samples, one per observation
    /// whose window refresh ran in this process.
    pub latency: Percentiles,
    /// Wall-clock duration of the whole run, seconds.
    pub wall_s: f64,
}

impl IngestReport {
    /// Multi-line human summary for the CLI.
    pub fn render(&self) -> String {
        let lat = if self.latency.is_empty() {
            "latency: no samples (all windows resumed or empty)".to_string()
        } else {
            let [p50, p95, p99] = self.latency.summary();
            format!(
                "latency s: p50 {p50:.3} p95 {p95:.3} p99 {p99:.3} ({} samples)",
                self.latency.len()
            )
        };
        format!(
            "ingested {} observations; closed {} windows ({} resumed from journal)\n\
             rejected: {} late, {} duplicate, {} unregistered\n\
             {lat}\n\
             sustained: {:.1} obs/s over {:.2}s",
            self.observations,
            self.windows_closed,
            self.windows_skipped,
            self.late,
            self.duplicates,
            self.unregistered,
            self.observations as f64 / self.wall_s.max(1e-9),
            self.wall_s,
        )
    }
}

/// One buffered observation: the measurement plus its arrival instant
/// (the latency clock starts the moment the feed line is read).
struct Rec {
    seq: u32,
    t: i64,
    lat: f64,
    lon: f64,
    alt_ft: f64,
    at: Instant,
}

struct State<'a> {
    cfg: &'a IngestConfig,
    hello_seen: bool,
    reg_lines: Vec<String>,
    registry: Option<Registry>,
    sources: Vec<String>,
    src_idx: HashMap<String, usize>,
    ended: Vec<bool>,
    max_t: Vec<i64>,
    base: Option<i64>,
    closed_windows: u64,
    /// Buffered observations not yet swept into a closed window.
    open: BTreeMap<(usize, u32), Vec<Rec>>,
    /// Accumulated observations of every closed window, per organized
    /// file — the source of truth for the full-file overwrites.
    done: BTreeMap<(usize, u32), Vec<Rec>>,
    seen: HashSet<(usize, u32, u32)>,
    completed: HashSet<usize>,
    journal: JournalWriter,
    rejects: std::io::BufWriter<std::fs::File>,
    samples: Vec<f64>,
    model: Option<crate::runtime::TrackModel>,
    observations: u64,
    windows_skipped: u64,
    late: u64,
    duplicates: u64,
    unregistered: u64,
}

impl<'a> State<'a> {
    fn new(cfg: &'a IngestConfig) -> Result<Self> {
        anyhow::ensure!(cfg.window_s > 0, "--window must be positive, got {}", cfg.window_s);
        anyhow::ensure!(cfg.lateness_s >= 0, "--lateness cannot be negative");
        std::fs::create_dir_all(&cfg.out_dir)?;
        // The journal plan pins the knobs that shape on-disk state, so
        // resuming with different flags is a typed plan-mismatch error
        // instead of a silently mixed tree.
        let fingerprint = format!(
            "window={} lateness={} format={} year={}",
            cfg.window_s,
            cfg.lateness_s,
            cfg.format.extension(),
            cfg.year
        );
        let mut plan = JournalPlan::new("ingest", [fingerprint.as_str()]);
        plan.ntasks = MAX_WINDOWS;
        let path = journal_path(&cfg.out_dir, "ingest");
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let (completed, journal) = if cfg.resume && path.exists() {
            let mut done = HashSet::new();
            for ev in load_verified(&path, &plan)? {
                if let JournalEvent::Ok { tasks, .. } = ev {
                    done.extend(tasks);
                }
            }
            (done, JournalWriter::append_to(&path)?)
        } else {
            (HashSet::new(), JournalWriter::create(&path, &plan)?)
        };
        let rejects = std::fs::OpenOptions::new()
            .create(true)
            .append(cfg.resume)
            .write(true)
            .truncate(!cfg.resume)
            .open(cfg.out_dir.join("rejected.log"))?;
        Ok(State {
            cfg,
            hello_seen: false,
            reg_lines: Vec::new(),
            registry: None,
            sources: Vec::new(),
            src_idx: HashMap::new(),
            ended: Vec::new(),
            max_t: Vec::new(),
            base: None,
            closed_windows: 0,
            open: BTreeMap::new(),
            done: BTreeMap::new(),
            seen: HashSet::new(),
            completed,
            journal,
            rejects: std::io::BufWriter::new(rejects),
            samples: Vec::new(),
            model: None,
            observations: 0,
            windows_skipped: 0,
            late: 0,
            duplicates: 0,
            unregistered: 0,
        })
    }

    fn source_index(&mut self, name: &str) -> usize {
        if let Some(&i) = self.src_idx.get(name) {
            return i;
        }
        let i = self.sources.len();
        self.sources.push(name.to_string());
        self.src_idx.insert(name.to_string(), i);
        self.ended.push(false);
        self.max_t.push(i64::MIN);
        i
    }

    /// Handle one event; `Ok(true)` means the feed said `bye`.
    fn on_event(&mut self, ev: FeedEvent) -> Result<bool> {
        if !self.hello_seen {
            match ev {
                FeedEvent::Hello { version: FEED_VERSION } => {
                    self.hello_seen = true;
                    return Ok(false);
                }
                FeedEvent::Hello { version } => bail!(
                    "unsupported feed version {version}; this build speaks {FEED_VERSION}"
                ),
                _ => bail!("feed did not start with a 'feed <version>' handshake"),
            }
        }
        match ev {
            FeedEvent::Hello { .. } => bail!("duplicate 'feed' handshake mid-stream"),
            FeedEvent::Reg { line } => self.reg_lines.push(line),
            FeedEvent::Obs(o) => self.on_obs(o)?,
            FeedEvent::End { source } => {
                let si = self.source_index(&source);
                self.ended[si] = true;
                self.close_ready(false)?;
            }
            FeedEvent::Bye => {
                self.close_ready(true)?;
                return Ok(true);
            }
        }
        Ok(false)
    }

    fn on_obs(&mut self, o: FeedObs) -> Result<()> {
        let at = Instant::now();
        if self.registry.is_none() {
            if self.reg_lines.is_empty() {
                bail!("feed sent an observation before its registry block");
            }
            let mut reg = Registry::default();
            reg.merge(crate::registry::parse_registry(&self.reg_lines.join("\n"))?);
            self.registry = Some(reg);
        }
        let si = self.source_index(&o.source);
        // Every arrival advances the source clock, accepted or not —
        // a late burst must still push the watermark forward.
        self.max_t[si] = self.max_t[si].max(o.t);
        let base = *self
            .base
            .get_or_insert_with(|| o.t.div_euclid(self.cfg.window_s) * self.cfg.window_s);
        if self.closed_windows > 0
            && o.t < base + self.closed_windows as i64 * self.cfg.window_s
        {
            self.late += 1;
            writeln!(
                self.rejects,
                "late {} {} seq={} t={}",
                o.source,
                icao24_hex(o.icao24),
                o.seq,
                o.t
            )?;
            return self.close_ready(false);
        }
        if !self.seen.insert((si, o.icao24, o.seq)) {
            self.duplicates += 1;
            writeln!(
                self.rejects,
                "duplicate {} {} seq={} t={}",
                o.source,
                icao24_hex(o.icao24),
                o.seq,
                o.t
            )?;
            return self.close_ready(false);
        }
        let registered = self
            .registry
            .as_ref()
            .is_some_and(|r| r.get(o.icao24).is_some());
        if !registered {
            // Batch stage 1 drops unregistered aircraft too; count them
            // so a feed/registry mismatch is visible, not silent.
            self.unregistered += 1;
            writeln!(
                self.rejects,
                "unregistered {} {} seq={} t={}",
                o.source,
                icao24_hex(o.icao24),
                o.seq,
                o.t
            )?;
            return self.close_ready(false);
        }
        self.observations += 1;
        self.open.entry((si, o.icao24)).or_default().push(Rec {
            seq: o.seq,
            t: o.t,
            lat: o.lat,
            lon: o.lon,
            alt_ft: o.alt_ft,
            at,
        });
        self.close_ready(false)
    }

    fn watermark(&self, si: usize) -> i64 {
        if self.ended[si] {
            i64::MAX
        } else {
            self.max_t[si].saturating_sub(self.cfg.lateness_s)
        }
    }

    /// Close every window whose bound the slowest watermark has passed
    /// (or, when draining at end of feed, every window with buffered
    /// observations left). Windows close strictly in index order so the
    /// journal's completed-set is a dense record. Windows that start
    /// past the newest observation ever seen stay open: no data can
    /// land in them, and without this floor an all-`end`ed feed (every
    /// watermark `+∞`) would close empty windows forever.
    fn close_ready(&mut self, drain: bool) -> Result<()> {
        let Some(base) = self.base else { return Ok(()) };
        let max_seen = self.max_t.iter().copied().max().unwrap_or(i64::MIN);
        loop {
            if self.closed_windows as usize >= MAX_WINDOWS {
                bail!("ingest exceeded its {MAX_WINDOWS}-window journal capacity");
            }
            let bound = base + (self.closed_windows as i64 + 1) * self.cfg.window_s;
            let ready = if drain {
                self.open.values().any(|v| !v.is_empty())
            } else {
                !self.sources.is_empty()
                    && bound - self.cfg.window_s <= max_seen
                    && (0..self.sources.len()).map(|i| self.watermark(i)).min()
                        >= Some(bound)
            };
            if !ready {
                return Ok(());
            }
            self.close_window(bound)?;
            self.closed_windows += 1;
        }
    }

    fn close_window(&mut self, bound: i64) -> Result<()> {
        let k = self.closed_windows as usize;
        // Sweep: everything below the bound leaves the open buffers and
        // joins the per-file accumulated sets. Window 0's sweep also
        // absorbs any disorder-shifted stragglers older than the base.
        let mut affected: BTreeSet<(usize, u32)> = BTreeSet::new();
        let mut arrivals: Vec<Instant> = Vec::new();
        let keys: Vec<(usize, u32)> = self.open.keys().copied().collect();
        for key in keys {
            let Some(buf) = self.open.get_mut(&key) else { continue };
            let mut kept = Vec::new();
            let mut moved = Vec::new();
            for r in buf.drain(..) {
                if r.t < bound {
                    moved.push(r);
                } else {
                    kept.push(r);
                }
            }
            *buf = kept;
            if buf.is_empty() {
                self.open.remove(&key);
            }
            if !moved.is_empty() {
                affected.insert(key);
                arrivals.extend(moved.iter().map(|r| r.at));
                self.done.entry(key).or_default().extend(moved);
            }
        }
        if self.completed.contains(&k) {
            // Resume: this window's refresh already landed before the
            // previous run died — the sweep above keeps the accumulated
            // sets correct for later windows, nothing is reprocessed.
            self.windows_skipped += 1;
            return Ok(());
        }
        let t0 = Instant::now();
        self.refresh(&affected)?;
        let now = Instant::now();
        self.samples
            .extend(arrivals.iter().map(|a| now.duration_since(*a).as_secs_f64()));
        // Journal *after* the refresh: the overwrites are idempotent, so
        // a crash between refresh and append only costs a re-refresh.
        self.journal.append(&JournalEvent::Ok {
            attempt: 0,
            worker: 0,
            busy_us: t0.elapsed().as_micros() as u64,
            tasks: vec![k],
            stats: vec![arrivals.len() as u64],
        })?;
        Ok(())
    }

    /// Incremental organize → archive → process over exactly the
    /// `(source, aircraft)` files a closing window touched.
    fn refresh(&mut self, affected: &BTreeSet<(usize, u32)>) -> Result<()> {
        if affected.is_empty() {
            return Ok(());
        }
        let organized = self.cfg.out_dir.join("organized");
        let archived = self.cfg.out_dir.join("archived");
        let registry = self.registry.as_ref().context("refresh before registry")?;
        let mut dirs: BTreeSet<PathBuf> = BTreeSet::new();
        for &(si, icao24) in affected {
            let entry = registry
                .get(icao24)
                .context("buffered aircraft vanished from the registry")?;
            let dir = organized.join(crate::hierarchy::opensky_path(self.cfg.year, entry));
            std::fs::create_dir_all(&dir)?;
            let mut recs: Vec<&Rec> =
                self.done.get(&(si, icao24)).map(|v| v.iter().collect()).unwrap_or_default();
            // Feed order within a file is its raw row order (the seq
            // number); batch organize preserves it, so so do we.
            recs.sort_by_key(|r| r.seq);
            let track = Track {
                icao24,
                obs: recs
                    .iter()
                    .map(|r| Observation {
                        t: r.t as f64,
                        lat: r.lat,
                        lon: r.lon,
                        alt_ft: r.alt_ft,
                    })
                    .collect(),
            };
            let name = format!("{}_{}.csv", icao24_hex(icao24), self.sources[si]);
            std::fs::write(dir.join(name), crate::tracks::write_csv(&[track]))?;
            dirs.insert(dir);
        }
        let plan = crate::archive::zipdir::ArchivePlan::plan_format(
            &organized,
            &archived,
            self.cfg.format,
        )?;
        let mut outputs = Vec::new();
        for task in &plan.tasks {
            if !dirs.contains(&task.src_dir) {
                continue;
            }
            match self.cfg.format {
                ArchiveFormat::Zip => crate::archive::zipdir::archive_dir(task)?,
                ArchiveFormat::Columnar => {
                    crate::archive::columnar::archive_dir_columnar(task)?
                }
            };
            outputs.push(task.dst.clone());
        }
        if self.model.is_none() {
            self.model = Some(crate::runtime::TrackModel::load(&self.cfg.artifact_dir)?);
        }
        let model = self.model.as_mut().context("model just loaded")?;
        let job = crate::workflow::stage3::ProcessJob {
            archive_dir: archived,
            out_dir: self.cfg.out_dir.join("processed"),
            artifact_dir: self.cfg.artifact_dir.clone(),
            segment: SegmentConfig::default(),
            format: self.cfg.format,
        };
        for dst in &outputs {
            crate::workflow::stage3::process_archive(dst, &job, model)?;
        }
        Ok(())
    }

    fn finish(mut self, wall_s: f64) -> Result<IngestReport> {
        // EOF without `bye` still drains — a truncated feed loses
        // nothing that arrived.
        self.close_ready(true)?;
        self.rejects.flush()?;
        Ok(IngestReport {
            observations: self.observations,
            windows_closed: self.closed_windows,
            windows_skipped: self.windows_skipped,
            late: self.late,
            duplicates: self.duplicates,
            unregistered: self.unregistered,
            latency: Percentiles::from_samples(self.samples),
            wall_s,
        })
    }
}

/// Run ingest over any line source (files, sockets, the in-process
/// bench pipe). Returns when the feed says `bye` or hits EOF.
pub fn run_reader(cfg: &IngestConfig, reader: impl BufRead) -> Result<IngestReport> {
    let t0 = Instant::now();
    let mut st = State::new(cfg)?;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        if st.on_event(FeedEvent::parse(&line)?)? {
            break;
        }
    }
    st.finish(t0.elapsed().as_secs_f64())
}

/// Run ingest over `cfg.feed` as a file.
pub fn run(cfg: &IngestConfig) -> Result<IngestReport> {
    let file = std::fs::File::open(&cfg.feed)
        .with_context(|| format!("opening feed {}", cfg.feed.display()))?;
    run_reader(cfg, std::io::BufReader::new(file))
}

/// `emproc ingest --feed FILE|- --out DIR [--window S] [--lateness S]
/// [--format zip|columnar] [--year Y] [--artifacts DIR] [--resume]`.
pub fn cmd(a: &ArgParser) -> Result<()> {
    let mut cfg = IngestConfig::new(
        PathBuf::from(a.required("feed")?),
        PathBuf::from(a.required("out")?),
    );
    cfg.window_s = a.get_num("window", cfg.window_s)?;
    cfg.lateness_s = a.get_num("lateness", cfg.lateness_s)?;
    cfg.format = ArchiveFormat::parse(a.get_or("format", "zip"))?;
    cfg.year = a.get_num("year", cfg.year)?;
    if let Some(dir) = a.get("artifacts") {
        cfg.artifact_dir = PathBuf::from(dir);
    }
    cfg.resume = a.has("resume");
    let report = if cfg.feed.as_os_str() == "-" {
        let stdin = std::io::stdin();
        run_reader(&cfg, stdin.lock())?
    } else {
        run(&cfg)?
    };
    println!("{}", report.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("emproc_ingest_{tag}_{}", std::process::id()))
    }

    fn cfg_for(tag: &str, window: i64, lateness: i64) -> IngestConfig {
        let out = tmp(tag);
        let _ = std::fs::remove_dir_all(&out);
        let mut cfg = IngestConfig::new(PathBuf::from("-"), out);
        cfg.window_s = window;
        cfg.lateness_s = lateness;
        cfg
    }

    fn run_lines(cfg: &IngestConfig, lines: &[String]) -> Result<IngestReport> {
        let text = lines.join("\n") + "\n";
        run_reader(cfg, std::io::BufReader::new(std::io::Cursor::new(text)))
    }

    // Feeds built around *unregistered* aircraft exercise the window /
    // watermark machinery without touching the PJRT model: rejected
    // observations still advance watermarks, and the windows they close
    // are empty, so `refresh` never runs.
    fn obs(src: &str, icao: u32, seq: u32, t: i64) -> String {
        FeedEvent::Obs(crate::stream::FeedObs {
            source: src.into(),
            icao24: icao,
            seq,
            t,
            lat: 1.0,
            lon: 2.0,
            alt_ft: 300.0,
        })
        .render()
    }

    fn header(reg_entries: &[&str]) -> Vec<String> {
        let mut v = vec![
            "feed 1".to_string(),
            format!("reg {}", crate::registry::HEADER),
        ];
        v.extend(reg_entries.iter().map(|e| format!("reg {e}")));
        v
    }

    #[test]
    fn handshake_and_version_are_enforced() {
        let cfg = cfg_for("hello", 600, 60);
        let err = run_lines(&cfg, &["feed 9".to_string()]).unwrap_err();
        assert!(err.to_string().contains("unsupported feed version 9"), "{err}");
        let err = run_lines(&cfg, &[obs("s", 1, 0, 100)]).unwrap_err();
        assert!(err.to_string().contains("handshake"), "{err}");
        let err = run_lines(
            &cfg,
            &["feed 1".to_string(), obs("s", 1, 0, 100)],
        )
        .unwrap_err();
        assert!(err.to_string().contains("before its registry"), "{err}");
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
    }

    #[test]
    fn late_and_duplicate_observations_hit_the_side_channel() {
        let cfg = cfg_for("reject", 100, 0);
        let mut lines = header(&["aaaaaa,light,4,2030"]);
        // Unknown aircraft 0x10: advances the watermark, closes windows,
        // never triggers a refresh.
        lines.push(obs("s", 0x10, 0, 1000));
        lines.push(obs("s", 0x10, 1, 1500)); // watermark 1500: closes [1000,1100), ...
        lines.push(obs("s", 0x10, 2, 1050)); // t inside a closed window -> late
        lines.push(obs("s", 0x10, 1, 1500)); // same (src, icao, seq) -> duplicate
        lines.push("end s".to_string());
        lines.push("bye".to_string());
        let report = run_lines(&cfg, &lines).unwrap();
        assert_eq!(report.late, 1);
        assert_eq!(report.duplicates, 1);
        assert_eq!(report.unregistered, 2, "the two accepted-shape obs are unregistered");
        assert_eq!(report.observations, 0);
        let log = std::fs::read_to_string(cfg.out_dir.join("rejected.log")).unwrap();
        assert!(log.contains("late s 000010 seq=2 t=1050"), "{log}");
        assert!(log.contains("duplicate s 000010 seq=1 t=1500"), "{log}");
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
    }

    #[test]
    fn empty_windows_close_cleanly_and_in_order() {
        let cfg = cfg_for("empty", 100, 0);
        let mut lines = header(&[]);
        lines.push(obs("s", 0x10, 0, 1000));
        // A quiet gap: the next observation is 5 windows later, so its
        // arrival closes [1000..1500) — four of them empty.
        lines.push(obs("s", 0x10, 1, 1550));
        lines.push("end s".to_string());
        lines.push("bye".to_string());
        let report = run_lines(&cfg, &lines).unwrap();
        // 5 watermark closes, then `end` lifts the watermark to +inf and
        // closes [1500,1600) — but nothing past the newest observation.
        assert_eq!(report.windows_closed, 6);
        assert_eq!(report.late, 0);
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
    }

    #[test]
    fn resume_with_different_knobs_is_a_plan_mismatch() {
        let cfg = cfg_for("knobs", 100, 0);
        let mut lines = header(&[]);
        lines.push(obs("s", 0x10, 0, 1000));
        lines.push("bye".to_string());
        run_lines(&cfg, &lines).unwrap();
        let mut resumed = cfg.clone();
        resumed.window_s = 200;
        resumed.resume = true;
        let err = run_lines(&resumed, &lines).unwrap_err();
        assert!(
            err.to_string().contains("journal"),
            "changing --window across a resume must fail journal verification: {err}"
        );
        let _ = std::fs::remove_dir_all(&cfg.out_dir);
    }
}
