//! Streaming ingest: a live observation feed plus watermark-triggered
//! incremental pipelines (DESIGN.md §15).
//!
//! The paper's pipeline is a batch job: all raw files exist up front and
//! three stages sweep them. This module reframes stage 0 as a *live feed*
//! of individual observations and re-runs the batch stage runners
//! incrementally as event-time windows close:
//!
//! * [`replay`] publishes a generated mini corpus as a line-delimited
//!   feed at a configurable rate multiplier (`emproc replay`),
//!   deterministically under a seed — same seed, byte-identical feed.
//! * [`ingest`] consumes a feed, buckets observations into event-time
//!   windows, tracks per-source watermarks, and on watermark advance
//!   re-runs organize → archive → process over exactly the files a
//!   closing window touched (`emproc ingest`).
//!
//! The feed grammar is line-delimited text (one [`FeedEvent`] per line):
//!
//! ```text
//! feed 1                                      # version handshake
//! reg <registry.csv line, verbatim>           # repeated; self-contained
//! obs <src> <icao24:06x> <seq> <t> <lat> <lon> <alt_ft>
//! end <src>                                   # source has no more obs
//! bye                                         # feed is complete
//! ```
//!
//! `src` is the raw-file stem (no `.csv`); `seq` is the observation's
//! 0-based index within its `(source, aircraft)` pair *in raw-file row
//! order*. Batch organize preserves raw row order — which is not
//! time-sorted when a corpus file revisits an aircraft — so the sequence
//! number, not the timestamp, is what lets ingest rebuild organized
//! files byte-identical to the batch pipeline's. Numeric fields render
//! at exactly the CSV codec's precision (`{t} {lat:.6} {lon:.6}
//! {alt:.1}`), so a feed round-trip loses nothing.

use anyhow::{bail, Context as _, Result};

/// Watermark-triggered incremental pipelines over a feed (`emproc ingest`).
pub mod ingest;
/// Deterministic corpus-to-feed publisher (`emproc replay`).
pub mod replay;

/// Feed protocol version this build speaks (the `feed <N>` handshake).
pub const FEED_VERSION: u32 = 1;

/// One observation on the wire: the source raw-file stem, the aircraft,
/// its per-`(source, aircraft)` sequence number, and the measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct FeedObs {
    /// Raw-file stem this observation came from (no `.csv`).
    pub source: String,
    /// ICAO 24-bit transponder address.
    pub icao24: u32,
    /// 0-based index within `(source, icao24)` in raw-file row order.
    pub seq: u32,
    /// Unix time, whole seconds (the CSV codec writes `t as i64`).
    pub t: i64,
    /// Latitude, degrees.
    pub lat: f64,
    /// Longitude, degrees.
    pub lon: f64,
    /// Barometric altitude, feet.
    pub alt_ft: f64,
}

/// One line of the feed protocol. [`FeedEvent::render`] and
/// [`FeedEvent::parse`] are exact inverses over valid lines.
#[derive(Debug, Clone, PartialEq)]
pub enum FeedEvent {
    /// `feed <version>` — must be the first line.
    Hello {
        /// Protocol version ([`FEED_VERSION`] in this build).
        version: u32,
    },
    /// `reg <line>` — one verbatim line of `registry.csv` (header
    /// included), making the feed self-contained.
    Reg {
        /// The registry CSV line, unmodified.
        line: String,
    },
    /// `obs ...` — one observation.
    Obs(FeedObs),
    /// `end <src>` — the named source will send no more observations.
    End {
        /// Raw-file stem whose observations are complete.
        source: String,
    },
    /// `bye` — the whole feed is complete.
    Bye,
}

impl FeedEvent {
    /// Render the event as its feed line (no trailing newline).
    pub fn render(&self) -> String {
        match self {
            FeedEvent::Hello { version } => format!("feed {version}"),
            FeedEvent::Reg { line } => format!("reg {line}"),
            FeedEvent::Obs(o) => format!(
                "obs {} {:06x} {} {} {:.6} {:.6} {:.1}",
                o.source, o.icao24, o.seq, o.t, o.lat, o.lon, o.alt_ft
            ),
            FeedEvent::End { source } => format!("end {source}"),
            FeedEvent::Bye => "bye".to_string(),
        }
    }

    /// Parse one feed line. Unknown verbs and malformed payloads are
    /// errors — a corrupted feed should fail loudly, not drop data.
    pub fn parse(line: &str) -> Result<FeedEvent> {
        let line = line.trim_end_matches(['\r', '\n']);
        let (verb, rest) = match line.split_once(' ') {
            Some((v, r)) => (v, r),
            None => (line, ""),
        };
        match verb {
            "feed" => {
                let version =
                    rest.trim().parse::<u32>().with_context(|| format!("bad feed version '{rest}'"))?;
                Ok(FeedEvent::Hello { version })
            }
            "reg" => Ok(FeedEvent::Reg { line: rest.to_string() }),
            "end" => {
                if rest.trim().is_empty() {
                    bail!("feed 'end' line is missing its source");
                }
                Ok(FeedEvent::End { source: rest.trim().to_string() })
            }
            "bye" if rest.is_empty() => Ok(FeedEvent::Bye),
            "obs" => {
                let parts: Vec<&str> = rest.split_whitespace().collect();
                if parts.len() != 7 {
                    bail!("feed obs line has {} fields, want 7: '{line}'", parts.len());
                }
                let icao24 = u32::from_str_radix(parts[1], 16)
                    .with_context(|| format!("bad icao24 '{}'", parts[1]))?;
                let num = |i: usize, what: &str| -> Result<f64> {
                    parts[i]
                        .parse::<f64>()
                        .with_context(|| format!("bad {what} '{}' in '{line}'", parts[i]))
                };
                Ok(FeedEvent::Obs(FeedObs {
                    source: parts[0].to_string(),
                    icao24,
                    seq: parts[2]
                        .parse::<u32>()
                        .with_context(|| format!("bad seq '{}'", parts[2]))?,
                    t: parts[3].parse::<i64>().with_context(|| format!("bad t '{}'", parts[3]))?,
                    lat: num(4, "lat")?,
                    lon: num(5, "lon")?,
                    alt_ft: num(6, "alt_ft")?,
                }))
            }
            other => bail!("unknown feed verb '{other}' in '{line}'"),
        }
    }
}

/// Writer half of [`pipe`]: each `write` sends one owned chunk down an
/// in-process channel. Dropping it closes the feed (reader sees EOF).
pub struct PipeWriter {
    tx: std::sync::mpsc::Sender<Vec<u8>>,
}

impl std::io::Write for PipeWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.tx.send(buf.to_vec()).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::BrokenPipe, "feed reader hung up")
        })?;
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Reader half of [`pipe`]: drains chunks in order; EOF once the writer
/// is dropped and the backlog is consumed.
pub struct PipeReader {
    rx: std::sync::mpsc::Receiver<Vec<u8>>,
    buf: Vec<u8>,
    pos: usize,
}

impl std::io::Read for PipeReader {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.buf.len() {
            match self.rx.recv() {
                Ok(chunk) => {
                    self.buf = chunk;
                    self.pos = 0;
                }
                Err(_) => return Ok(0), // writer dropped: clean EOF
            }
        }
        let n = (self.buf.len() - self.pos).min(out.len());
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// An in-process byte pipe connecting a replayer thread to an ingest
/// reader in the same process — `emproc bench streaming` uses it to
/// measure feed→processed-row latency without touching a socket or a
/// file. Unbounded: the replayer never blocks on a slow consumer.
pub fn pipe() -> (PipeWriter, PipeReader) {
    let (tx, rx) = std::sync::mpsc::channel();
    (PipeWriter { tx }, PipeReader { rx, buf: Vec::new(), pos: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing;

    #[test]
    fn every_event_kind_round_trips_through_render_and_parse() {
        let events = [
            FeedEvent::Hello { version: 1 },
            FeedEvent::Reg { line: "icao24,type,seats,expires".into() },
            FeedEvent::Reg { line: "0000a1,light,4,2024".into() },
            FeedEvent::Obs(FeedObs {
                source: "mon_d0_h9".into(),
                icao24: 0xabc123,
                seq: 17,
                t: 1_500_003_000,
                lat: -33.123456,
                lon: 151.654321,
                alt_ft: 3500.0,
            }),
            FeedEvent::End { source: "mon_d0_h9".into() },
            FeedEvent::Bye,
        ];
        for ev in &events {
            let line = ev.render();
            let back = FeedEvent::parse(&line).unwrap();
            assert_eq!(&back, ev, "line was '{line}'");
        }
    }

    #[test]
    fn obs_lines_round_trip_at_csv_precision() {
        testing::check("feed_obs_roundtrip", |rng| {
            // Values quantized the way the CSV codec writes them: t as
            // i64, lat/lon at 1e-6, alt at 0.1 — the feed must carry
            // exactly that much.
            let q = |v: f64, s: f64| (v * s).round() / s;
            let o = FeedObs {
                source: format!("src_{}", rng.below(10)),
                icao24: rng.below(1 << 24) as u32,
                seq: rng.below(1000) as u32,
                t: 1_500_000_000 + rng.below(200_000) as i64,
                lat: q(rng.uniform(-90.0, 90.0), 1e6),
                lon: q(rng.uniform(-180.0, 180.0), 1e6),
                alt_ft: q(rng.uniform(0.0, 40_000.0), 10.0),
            };
            let back = FeedEvent::parse(&FeedEvent::Obs(o.clone()).render())
                .map_err(|e| e.to_string())?;
            prop_assert!(back == FeedEvent::Obs(o.clone()), "{back:?} != {o:?}");
            Ok(())
        });
    }

    #[test]
    fn malformed_lines_are_rejected_with_context() {
        for bad in [
            "obs short",
            "obs s zz 0 1 2.0 3.0 4.0",
            "obs s 0000a1 x 1 2.0 3.0 4.0",
            "feed banana",
            "warble 1 2 3",
            "end ",
        ] {
            assert!(FeedEvent::parse(bad).is_err(), "'{bad}' should not parse");
        }
        // A version mismatch still *parses* — rejecting it is ingest's
        // job, with a typed error naming both versions.
        assert_eq!(FeedEvent::parse("feed 9").unwrap(), FeedEvent::Hello { version: 9 });
    }

    #[test]
    fn pipe_moves_bytes_in_order_and_eofs_when_writer_drops() {
        use std::io::{Read as _, Write as _};
        let (mut w, mut r) = pipe();
        w.write_all(b"hello ").unwrap();
        w.write_all(b"world").unwrap();
        drop(w);
        let mut got = String::new();
        r.read_to_string(&mut got).unwrap();
        assert_eq!(got, "hello world");
    }
}
