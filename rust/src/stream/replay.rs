//! `emproc replay` — publish a generated corpus as a live observation
//! feed (DESIGN.md §15).
//!
//! The replayer reads a raw corpus directory (the batch pipeline's
//! `raw/`: per-hour CSV files plus `registry.csv`) and emits every
//! observation as one [`FeedEvent::Obs`] line, globally ordered by
//! event time plus an optional seeded disorder shift. The *content* of
//! the feed — which lines, in which order — depends only on the corpus
//! and `--seed`; `--rate` and `--jitter` shape timing only. Same seed,
//! byte-identical feed, at any rate.

use super::{FeedEvent, FeedObs, FEED_VERSION};
use crate::cli::ArgParser;
use crate::util::Rng;
use anyhow::{Context as _, Result};
use std::io::Write;
use std::path::PathBuf;

/// Liveness cap on any single inter-event wait, seconds of wall time.
/// The mini corpora have multi-hour event-time gaps between raw files;
/// pacing those faithfully at modest rates would stall the feed for
/// minutes. Timing only — the byte stream is unaffected.
pub const MAX_SLEEP_S: f64 = 1.0;

/// Everything `emproc replay` needs.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Raw corpus directory (`registry.csv` + per-hour CSV files).
    pub data_dir: PathBuf,
    /// Rate multiplier over event time: 60 replays a minute of data per
    /// wall second. `<= 0` disables pacing entirely (full speed).
    pub rate: f64,
    /// Seed for disorder shifts and pacing jitter.
    pub seed: u64,
    /// Uniform `[0, jitter_s)` seconds of *event time* added to each
    /// inter-event wait before rate scaling (burst shaping; timing only).
    pub jitter_s: f64,
    /// Uniform `[-disorder_s, disorder_s)` event-time shift applied to
    /// each observation's emission slot — reorders feed *content*
    /// deterministically, modelling out-of-order arrival.
    pub disorder_s: f64,
}

/// What [`replay`] emitted, for the stderr summary and tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplayStats {
    /// Sources (raw files) replayed to completion (`end` lines).
    pub sources: u64,
    /// Observation lines emitted.
    pub observations: u64,
    /// Total feed lines, handshake and terminator included.
    pub events: u64,
}

/// Build the full feed deterministically: every event paired with its
/// emission slot on the event-time axis (used only for pacing).
/// Consumes `rng` for disorder draws; [`replay`] keeps drawing jitter
/// from the same stream afterwards, so one seed governs both.
pub fn feed_events(cfg: &ReplayConfig, rng: &mut Rng) -> Result<Vec<(f64, FeedEvent)>> {
    let reg_path = cfg.data_dir.join("registry.csv");
    let reg_text = std::fs::read_to_string(&reg_path)
        .with_context(|| format!("reading {}", reg_path.display()))?;
    let files = crate::workflow::stage1::list_raw_files(&cfg.data_dir)?;
    anyhow::ensure!(
        !files.is_empty(),
        "no raw CSV files under {} to replay",
        cfg.data_dir.display()
    );

    // One emission slot per observation: event time plus the seeded
    // disorder shift. Draw order is fixed (files sorted, tracks sorted
    // by icao24, observations in raw row order), so the shifts — and
    // therefore the emitted byte stream — depend only on the seed.
    let mut stems = Vec::with_capacity(files.len());
    let mut slots: Vec<(f64, usize, FeedObs)> = Vec::new();
    for (si, (path, _bytes)) in files.iter().enumerate() {
        let stem = path
            .file_stem()
            .and_then(std::ffi::OsStr::to_str)
            .with_context(|| format!("non-utf8 raw file name {}", path.display()))?
            .to_string();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        for track in crate::tracks::parse_csv(&text)? {
            for (seq, o) in track.obs.iter().enumerate() {
                let shift = if cfg.disorder_s > 0.0 {
                    rng.uniform(-cfg.disorder_s, cfg.disorder_s)
                } else {
                    0.0
                };
                slots.push((
                    o.t + shift,
                    si,
                    FeedObs {
                        source: stem.clone(),
                        icao24: track.icao24,
                        seq: seq as u32,
                        t: o.t as i64,
                        lat: o.lat,
                        lon: o.lon,
                        alt_ft: o.alt_ft,
                    },
                ));
            }
        }
        stems.push(stem);
    }
    // Total order: emission slot, then (source, aircraft, seq) as an
    // exact tie-break so equal slots cannot reorder across runs.
    slots.sort_by(|a, b| {
        a.0.total_cmp(&b.0)
            .then(a.1.cmp(&b.1))
            .then(a.2.icao24.cmp(&b.2.icao24))
            .then(a.2.seq.cmp(&b.2.seq))
    });

    let mut remaining = vec![0usize; stems.len()];
    for (_, si, _) in &slots {
        remaining[*si] += 1;
    }
    let first_t = slots.first().map_or(0.0, |s| s.0);
    let mut events = Vec::with_capacity(slots.len() + stems.len() + reg_text.lines().count() + 2);
    events.push((first_t, FeedEvent::Hello { version: FEED_VERSION }));
    for line in reg_text.lines() {
        events.push((first_t, FeedEvent::Reg { line: line.to_string() }));
    }
    // A raw file that parsed to zero observations is complete before the
    // feed starts — say so up front rather than never.
    for (si, stem) in stems.iter().enumerate() {
        if remaining[si] == 0 {
            events.push((first_t, FeedEvent::End { source: stem.clone() }));
        }
    }
    let mut last_t = first_t;
    for (t, si, obs) in slots {
        events.push((t, FeedEvent::Obs(obs)));
        remaining[si] -= 1;
        if remaining[si] == 0 {
            events.push((t, FeedEvent::End { source: stems[si].clone() }));
        }
        last_t = t;
    }
    events.push((last_t, FeedEvent::Bye));
    Ok(events)
}

/// Emit the feed to `out`, pacing inter-event gaps by `cfg.rate` (with
/// seeded jitter, each wait capped at [`MAX_SLEEP_S`]). With pacing the
/// writer is flushed per line so a downstream ingest sees events live.
pub fn replay(cfg: &ReplayConfig, out: &mut dyn Write) -> Result<ReplayStats> {
    let mut rng = Rng::new(cfg.seed);
    let events = feed_events(cfg, &mut rng)?;
    let paced = cfg.rate > 0.0;
    let mut last_t = events.first().map_or(0.0, |e| e.0);
    let mut stats = ReplayStats { sources: 0, observations: 0, events: events.len() as u64 };
    for (t, ev) in &events {
        if paced {
            let jitter =
                if cfg.jitter_s > 0.0 { rng.uniform(0.0, cfg.jitter_s) } else { 0.0 };
            let wait = ((t - last_t).max(0.0) + jitter) / cfg.rate;
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wait.min(MAX_SLEEP_S)));
            }
        }
        out.write_all(ev.render().as_bytes())?;
        out.write_all(b"\n")?;
        if paced {
            out.flush()?;
        }
        match ev {
            FeedEvent::Obs(_) => stats.observations += 1,
            FeedEvent::End { .. } => stats.sources += 1,
            _ => {}
        }
        last_t = *t;
    }
    out.flush()?;
    Ok(stats)
}

/// `emproc replay --data DIR [--rate F] [--seed N] [--jitter S]
/// [--disorder S] [--out FILE|-]` — feed to stdout (or `--out`), summary
/// to stderr so a pipe into `emproc ingest` stays clean.
pub fn cmd(a: &ArgParser) -> Result<()> {
    let cfg = ReplayConfig {
        data_dir: PathBuf::from(a.required("data")?),
        rate: a.get_num("rate", 0.0f64)?,
        seed: a.get_num("seed", 42u64)?,
        jitter_s: a.get_num("jitter", 0.0f64)?,
        disorder_s: a.get_num("disorder", 0.0f64)?,
    };
    let t0 = std::time::Instant::now();
    let stats = match a.get("out") {
        Some(path) if path != "-" => {
            let file = std::fs::File::create(path)
                .with_context(|| format!("creating {path}"))?;
            replay(&cfg, &mut std::io::BufWriter::new(file))?
        }
        _ => {
            let stdout = std::io::stdout();
            replay(&cfg, &mut std::io::BufWriter::new(stdout.lock()))?
        }
    };
    eprintln!(
        "replayed {} observations from {} sources ({} feed lines) in {:.2}s",
        stats.observations,
        stats.sources,
        stats.events,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::pipeline::{Pipeline, PipelineConfig};

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("emproc_replay_{tag}_{}", std::process::id()))
    }

    fn gen_corpus(dir: &PathBuf) -> usize {
        let _ = std::fs::remove_dir_all(dir);
        let mut cfg = PipelineConfig::small(dir.clone());
        cfg.days = 1;
        cfg.registry_size = 20;
        cfg.max_file_bytes = 8_000;
        let (_registry, raw_files) = Pipeline::new(cfg).generate().unwrap();
        raw_files
    }

    fn feed_bytes(data: PathBuf, seed: u64, disorder: f64) -> Vec<u8> {
        let cfg = ReplayConfig { data_dir: data, rate: 0.0, seed, jitter_s: 0.0, disorder_s: disorder };
        let mut out = Vec::new();
        replay(&cfg, &mut out).unwrap();
        out
    }

    #[test]
    fn same_seed_replays_a_byte_identical_feed() {
        let dir = tmp("det");
        let raw_files = gen_corpus(&dir);
        assert!(raw_files > 0);
        let raw = dir.join("raw");
        let a = feed_bytes(raw.clone(), 7, 30.0);
        let b = feed_bytes(raw.clone(), 7, 30.0);
        assert_eq!(a, b, "same seed must replay byte-identically");
        // Different seeds shuffle different disorder shifts: content order
        // differs, but only when disorder is in play.
        let c = feed_bytes(raw.clone(), 8, 30.0);
        assert_ne!(a, c, "disorder shifts should depend on the seed");
        let d0a = feed_bytes(raw.clone(), 7, 0.0);
        let d0b = feed_bytes(raw, 8, 0.0);
        assert_eq!(d0a, d0b, "without disorder the seed must not leak into content");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn feed_is_well_formed_and_complete() {
        let dir = tmp("shape");
        gen_corpus(&dir);
        let bytes = feed_bytes(dir.join("raw"), 42, 45.0);
        let text = String::from_utf8(bytes).unwrap();
        let events: Vec<FeedEvent> =
            text.lines().map(|l| FeedEvent::parse(l).unwrap()).collect();
        assert_eq!(events.first(), Some(&FeedEvent::Hello { version: FEED_VERSION }));
        assert_eq!(events.last(), Some(&FeedEvent::Bye));
        // Registry rides in the feed verbatim, header first.
        let regs: Vec<&FeedEvent> =
            events.iter().filter(|e| matches!(e, FeedEvent::Reg { .. })).collect();
        assert!(matches!(regs[0], FeedEvent::Reg { line } if line == crate::registry::HEADER));
        // Every source ends exactly once, and never before its last obs.
        let mut last_obs = std::collections::HashMap::new();
        let mut ended = std::collections::HashMap::new();
        for (i, ev) in events.iter().enumerate() {
            match ev {
                FeedEvent::Obs(o) => {
                    assert!(!ended.contains_key(&o.source), "obs after end for {}", o.source);
                    last_obs.insert(o.source.clone(), i);
                }
                FeedEvent::End { source } => {
                    assert!(ended.insert(source.clone(), i).is_none(), "double end {source}");
                }
                _ => {}
            }
        }
        for (src, i) in &last_obs {
            assert!(ended[src] > *i, "end for {src} precedes its last obs");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
