//! Task organization (§II.B) and batch distribution (§II.A): the layer
//! between "a pile of input files" and "work handed to processes".
//!
//! The paper's stage-1 experiments vary exactly two knobs upstream of the
//! allocation protocol:
//!
//! * **task organization** — the *order* tasks are visited in
//!   ([`TaskOrder`], [`order_tasks`]): chronological (Table I), largest
//!   first (Table II, "organizing tasks by size always outperformed
//!   chronological"), random (§IV.C processing runs), or filename-sorted
//!   (the LLMapReduce default that made §IV.B archiving pathological);
//! * **task distribution** — how a pre-assigned batch run splits the
//!   ordered list across workers ([`Distribution`], [`distribute`]):
//!   contiguous *block* or round-robin *cyclic*. Self-scheduled runs skip
//!   this and pull from the ordered list dynamically
//!   (see [`crate::sched`]).
//!
//! A [`Task`] is deliberately lightweight — an index plus the cost drivers
//! the simulator's [`crate::simcluster::CostModel`] and the orderings need
//! (bytes, observations, DEM footprint, a chronological key, a name). One
//! `Task` = one raw file (stage 1), one bottom directory (stage 2), or one
//! aircraft archive / deidentified id (stage 3, §V).

use crate::util::Rng;
use std::cmp::Reverse;
use std::sync::Arc;

/// One schedulable unit of work.
#[derive(Debug, Clone, PartialEq)]
pub struct Task {
    /// Stable identifier; by convention the index into the builder's list.
    pub id: usize,
    /// Input bytes (stages 1/2 cost driver). Stage-3 builders reuse this
    /// field for the fixed per-task cost via [`Task::set_fixed_cost_s`].
    pub bytes: u64,
    /// Observation count (stage-3 dominant cost driver).
    pub obs: u64,
    /// DEM cells the task touches (stage-3 cost driver, §V).
    pub dem_cells: u64,
    /// Chronological sort key (ticks; any monotone encoding of time).
    pub chrono_key: u64,
    /// File/archive name (the [`TaskOrder::FilenameSorted`] key). Shared
    /// and immutable, so cloning a `Task` — 100k-task corpora get copied
    /// into per-stage lists and traces — bumps a refcount instead of
    /// allocating a fresh `String` per task.
    pub name: Arc<str>,
}

impl Task {
    /// Build stage-1 tasks from a dataset manifest: one task per raw file,
    /// with the manifest's (day, hour) as the chronological key and ~110
    /// bytes per CSV observation line.
    pub fn from_manifest(manifest: &crate::datasets::FileManifest) -> Vec<Task> {
        manifest
            .entries
            .iter()
            .enumerate()
            .map(|(i, e)| Task {
                id: i,
                bytes: e.size,
                obs: e.size / 110,
                dem_cells: 0,
                chrono_key: e.day as u64 * 24 + e.hour as u64,
                name: e.name.as_str().into(),
            })
            .collect()
    }
}

/// Per-task scalar cost estimate feeding LPT packing and the
/// cost-descending task order. The drivers and weights mirror
/// `CostModel::paper_calibrated` (stage-1/2 work is `bytes / 1e6` MB;
/// stage 3 adds `obs * c_obs + dem_cells * c_dem`), so the estimate ranks
/// tasks the same way the calibrated simulator charges for them — the
/// absolute scale is irrelevant, only the ordering and ratios matter.
#[derive(Debug, Clone)]
pub struct CostEstimate {
    costs: Vec<f64>,
}

impl CostEstimate {
    /// The scalar estimate for one task.
    pub fn of(task: &Task) -> f64 {
        task.bytes as f64 * 1e-6 + task.obs as f64 * 5.0e-3 + task.dem_cells as f64 * 2.0e-4
    }

    /// Estimates for a builder's task list, indexed like the list (by
    /// convention `tasks[i].id == i`, so this is also indexed by id).
    pub fn from_tasks(tasks: &[Task]) -> Self {
        CostEstimate { costs: tasks.iter().map(Self::of).collect() }
    }

    /// Cost of task `id` (0.0 for ids beyond the estimated list — the
    /// neutral value: an unknown task neither attracts nor repels a bin).
    pub fn get(&self, id: usize) -> f64 {
        self.costs.get(id).copied().unwrap_or(0.0)
    }

    /// All costs, indexed by task id.
    pub fn as_slice(&self) -> &[f64] {
        &self.costs
    }

    /// All costs, owned — e.g. for [`crate::launch::RunOptions`]'s
    /// `cost` field.
    pub fn into_vec(self) -> Vec<f64> {
        self.costs
    }
}

/// Task-organization policy (§II.B "organize" step).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskOrder {
    /// Ascending [`Task::chrono_key`] (Table I).
    Chronological,
    /// Descending [`Task::bytes`], then descending [`Task::obs`] for
    /// byte-less stage-3 tasks (Table II; LPT-style).
    LargestFirst,
    /// Seeded deterministic shuffle (§IV.C processing runs).
    Random(u64),
    /// Ascending [`Task::name`] (the LLMapReduce listing order, §IV.B).
    FilenameSorted,
    /// Descending [`CostEstimate`] — the self-scheduled counterpart of
    /// LPT packing: grant the most expensive work first so the tail of
    /// the run is made of cheap tasks (`--policy lpt`).
    CostDescending,
}

/// Visit order for `tasks` under `order`: a permutation of `0..tasks.len()`
/// of indices into `tasks`. All sorts are stable with index tie-breaks, so
/// the result is deterministic for any input.
pub fn order_tasks(tasks: &[Task], order: TaskOrder) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..tasks.len()).collect();
    match order {
        TaskOrder::Chronological => {
            idx.sort_by_key(|&i| (tasks[i].chrono_key, i));
        }
        TaskOrder::LargestFirst => {
            idx.sort_by_key(|&i| (Reverse(tasks[i].bytes), Reverse(tasks[i].obs), i));
        }
        TaskOrder::Random(seed) => {
            let mut rng = Rng::new(seed);
            rng.shuffle(&mut idx);
        }
        TaskOrder::FilenameSorted => {
            idx.sort_by(|&a, &b| tasks[a].name.cmp(&tasks[b].name).then(a.cmp(&b)));
        }
        TaskOrder::CostDescending => {
            let cost = CostEstimate::from_tasks(tasks);
            idx.sort_by(|&a, &b| {
                cost.get(b).total_cmp(&cost.get(a)).then(a.cmp(&b))
            });
        }
    }
    idx
}

/// Batch distribution policy (§II.A): how pMatlab/LLMapReduce pre-assign
/// an ordered task list to workers with no manager involvement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Distribution {
    /// Contiguous chunks: worker `w` gets the `w`-th slice of the ordered
    /// list. Pathological when cost is correlated with order (§IV.B).
    Block,
    /// Round-robin: worker `w` gets `ordered[w]`, `ordered[w + W]`, ...
    Cyclic,
    /// Longest-processing-time-first bin packing: tasks are assigned
    /// cost-descending, each to the currently least-loaded worker (tie:
    /// lowest index). Balances *cost*, not count — [`distribute`] runs it
    /// with unit costs (degenerating to round-robin); feed real estimates
    /// through [`distribute_costed`].
    Lpt,
}

/// Split `ordered` across `nworkers` queues. The result is always a
/// partition: every element of `ordered` appears in exactly one queue, in
/// its original relative order (block/cyclic), and exactly `nworkers`
/// queues are returned (later ones empty when there are more workers than
/// tasks). [`Distribution::Lpt`] packs with unit costs here; use
/// [`distribute_costed`] to feed a real [`CostEstimate`].
pub fn distribute(ordered: &[usize], nworkers: usize, dist: Distribution) -> Vec<Vec<usize>> {
    distribute_costed(ordered, nworkers, dist, &[])
}

/// Cost-aware [`distribute`]: `cost` is indexed by task id (see
/// [`CostEstimate::as_slice`]; ids beyond it cost 0.0, and an empty slice
/// means unit costs). Block and cyclic ignore the costs entirely — their
/// assignment is positional by definition — so this is a drop-in superset
/// of [`distribute`]; only [`Distribution::Lpt`] consumes them.
pub fn distribute_costed(
    ordered: &[usize],
    nworkers: usize,
    dist: Distribution,
    cost: &[f64],
) -> Vec<Vec<usize>> {
    assert!(nworkers >= 1, "need at least one worker");
    let mut queues: Vec<Vec<usize>> = vec![Vec::new(); nworkers];
    match dist {
        Distribution::Block => {
            let base = ordered.len() / nworkers;
            let rem = ordered.len() % nworkers;
            let mut start = 0usize;
            for (w, queue) in queues.iter_mut().enumerate() {
                let len = base + usize::from(w < rem);
                queue.extend_from_slice(&ordered[start..start + len]);
                start += len;
            }
        }
        Distribution::Cyclic => {
            for (i, &t) in ordered.iter().enumerate() {
                queues[i % nworkers].push(t);
            }
        }
        Distribution::Lpt => {
            let unknown = if cost.is_empty() { 1.0 } else { 0.0 };
            let cost_of = |t: usize| -> f64 { cost.get(t).copied().unwrap_or(unknown) };
            // Visit positions cost-descending (stable: ties keep their
            // order in `ordered`), assigning each task to the least-loaded
            // queue so far — the classic LPT greedy, deterministic for any
            // input.
            let mut pos: Vec<usize> = (0..ordered.len()).collect();
            pos.sort_by(|&a, &b| {
                cost_of(ordered[b]).total_cmp(&cost_of(ordered[a])).then(a.cmp(&b))
            });
            let mut load = vec![0.0f64; nworkers];
            for p in pos {
                let t = ordered[p];
                // Least-loaded bin, lowest index on ties (strict `<` keeps
                // the earliest minimum).
                let mut w = 0usize;
                for i in 1..nworkers {
                    if load[i] < load[w] {
                        w = i;
                    }
                }
                queues[w].push(t);
                load[w] += cost_of(t);
            }
        }
    }
    queues
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{DatasetKind, FileEntry, FileManifest};
    use crate::prop_assert;
    use crate::testing::{self, gen};

    fn mk_tasks(rng: &mut Rng, n: usize) -> Vec<Task> {
        (0..n)
            .map(|i| Task {
                id: i,
                bytes: gen::file_size(rng),
                obs: rng.below(10_000) as u64,
                dem_cells: rng.below(1_000) as u64,
                chrono_key: rng.below(500) as u64,
                name: format!("f{:04}_{:03}.csv", rng.below(5_000), i).into(),
            })
            .collect()
    }

    fn is_permutation(idx: &[usize], n: usize) -> bool {
        let mut seen = vec![false; n];
        if idx.len() != n {
            return false;
        }
        for &i in idx {
            if i >= n || seen[i] {
                return false;
            }
            seen[i] = true;
        }
        true
    }

    #[test]
    fn order_tasks_is_a_permutation_with_documented_keys() {
        testing::check("order_tasks permutation", |rng| {
            let n = gen::task_count(rng);
            let tasks = mk_tasks(rng, n);
            for order in [
                TaskOrder::Chronological,
                TaskOrder::LargestFirst,
                TaskOrder::Random(rng.below(1_000) as u64),
                TaskOrder::FilenameSorted,
                TaskOrder::CostDescending,
            ] {
                let idx = order_tasks(&tasks, order);
                prop_assert!(
                    is_permutation(&idx, n),
                    "{order:?} not a permutation of 0..{n}: {idx:?}"
                );
                match order {
                    TaskOrder::Chronological => {
                        for pair in idx.windows(2) {
                            prop_assert!(
                                tasks[pair[0]].chrono_key <= tasks[pair[1]].chrono_key,
                                "chrono keys out of order"
                            );
                        }
                    }
                    TaskOrder::LargestFirst => {
                        for pair in idx.windows(2) {
                            prop_assert!(
                                tasks[pair[0]].bytes >= tasks[pair[1]].bytes,
                                "sizes out of order"
                            );
                        }
                    }
                    TaskOrder::FilenameSorted => {
                        for pair in idx.windows(2) {
                            prop_assert!(
                                tasks[pair[0]].name <= tasks[pair[1]].name,
                                "names out of order"
                            );
                        }
                    }
                    TaskOrder::CostDescending => {
                        let cost = CostEstimate::from_tasks(&tasks);
                        for pair in idx.windows(2) {
                            prop_assert!(
                                cost.get(pair[0]) >= cost.get(pair[1]),
                                "costs out of order"
                            );
                        }
                    }
                    TaskOrder::Random(_) => {}
                }
            }
            Ok(())
        });
    }

    #[test]
    fn random_order_is_seed_deterministic() {
        let mut rng = Rng::new(11);
        let tasks = mk_tasks(&mut rng, 300);
        assert_eq!(
            order_tasks(&tasks, TaskOrder::Random(9)),
            order_tasks(&tasks, TaskOrder::Random(9))
        );
        assert_ne!(
            order_tasks(&tasks, TaskOrder::Random(9)),
            order_tasks(&tasks, TaskOrder::Random(10))
        );
    }

    #[test]
    fn stable_tie_breaks_preserve_index_order() {
        let tasks: Vec<Task> = (0..10)
            .map(|i| Task {
                id: i,
                bytes: 100,
                obs: 5,
                dem_cells: 0,
                chrono_key: 7,
                name: "same".into(),
            })
            .collect();
        let want: Vec<usize> = (0..10).collect();
        for order in [
            TaskOrder::Chronological,
            TaskOrder::LargestFirst,
            TaskOrder::FilenameSorted,
            TaskOrder::CostDescending,
        ] {
            assert_eq!(order_tasks(&tasks, order), want, "{order:?}");
        }
    }

    #[test]
    fn distribute_returns_a_partition() {
        testing::check("distribute partition", |rng| {
            let n = gen::task_count(rng);
            let nworkers = gen::worker_count(rng);
            let ordered: Vec<usize> = order_tasks(&mk_tasks(rng, n), TaskOrder::Random(3));
            for dist in [Distribution::Block, Distribution::Cyclic] {
                let queues = distribute(&ordered, nworkers, dist);
                prop_assert!(
                    queues.len() == nworkers,
                    "{dist:?}: {} queues for {nworkers} workers",
                    queues.len()
                );
                let mut count = vec![0usize; n];
                for q in &queues {
                    for &t in q {
                        prop_assert!(t < n, "{dist:?}: out-of-range index {t}");
                        count[t] += 1;
                    }
                }
                prop_assert!(
                    count.iter().all(|&c| c == 1),
                    "{dist:?}: not a partition (counts {count:?})"
                );
                // Fair sizes: queue lengths differ by at most one.
                let lo = queues.iter().map(Vec::len).min().unwrap_or(0);
                let hi = queues.iter().map(Vec::len).max().unwrap_or(0);
                prop_assert!(hi - lo <= 1, "{dist:?}: unfair split {lo}..{hi}");
            }
            Ok(())
        });
    }

    #[test]
    fn block_is_contiguous_and_cyclic_interleaves() {
        let ordered: Vec<usize> = (0..7).collect();
        let block = distribute(&ordered, 3, Distribution::Block);
        assert_eq!(block, vec![vec![0, 1, 2], vec![3, 4], vec![5, 6]]);
        let cyclic = distribute(&ordered, 3, Distribution::Cyclic);
        assert_eq!(cyclic, vec![vec![0, 3, 6], vec![1, 4], vec![2, 5]]);
    }

    #[test]
    fn distribute_handles_more_workers_than_tasks() {
        let ordered = [4usize, 2];
        for dist in [Distribution::Block, Distribution::Cyclic] {
            let queues = distribute(&ordered, 5, dist);
            assert_eq!(queues.len(), 5);
            assert_eq!(queues.iter().map(Vec::len).sum::<usize>(), 2);
            // The populated queues are the leading ones, in order.
            assert_eq!(queues[0], vec![4]);
            assert_eq!(queues[1], vec![2]);
            assert!(queues[2..].iter().all(Vec::is_empty), "{dist:?}");
        }
    }

    #[test]
    fn distribute_empty_ordered_yields_all_empty_queues() {
        for dist in [Distribution::Block, Distribution::Cyclic] {
            let queues = distribute(&[], 4, dist);
            assert_eq!(queues.len(), 4, "{dist:?}");
            assert!(queues.iter().all(Vec::is_empty), "{dist:?}");
        }
    }

    #[test]
    fn distribute_queue_lengths_match_closed_forms() {
        // Block worker `w` holds `n/W + (w < n%W)` tasks, contiguous in
        // the ordered list; cyclic worker `w` holds `ceil((n-w)/W)` tasks,
        // striding by `W` — including the workers > tasks regime.
        testing::check("distribute queue lengths", |rng| {
            let n = rng.below(500);
            let nworkers = 1 + rng.below(600); // frequently > n
            let ordered: Vec<usize> = (0..n).collect();
            let base = n / nworkers;
            let rem = n % nworkers;
            let block = distribute(&ordered, nworkers, Distribution::Block);
            let cyclic = distribute(&ordered, nworkers, Distribution::Cyclic);
            for w in 0..nworkers {
                let bwant = base + usize::from(w < rem);
                prop_assert!(
                    block[w].len() == bwant,
                    "block[{w}] len {} != {bwant} (n={n}, W={nworkers})",
                    block[w].len()
                );
                let cwant = if w < n { (n - w).div_ceil(nworkers) } else { 0 };
                prop_assert!(
                    cyclic[w].len() == cwant,
                    "cyclic[{w}] len {} != {cwant} (n={n}, W={nworkers})",
                    cyclic[w].len()
                );
            }
            // Structure: block queues are contiguous runs of the ordered
            // list, cyclic queues stride by the worker count.
            for q in &block {
                for pair in q.windows(2) {
                    prop_assert!(pair[1] == pair[0] + 1, "block not contiguous: {q:?}");
                }
            }
            for q in &cyclic {
                for pair in q.windows(2) {
                    prop_assert!(
                        pair[1] == pair[0] + nworkers,
                        "cyclic stride broken (W={nworkers}): {q:?}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lpt_closed_form_on_a_skewed_cost_vector() {
        // Costs [5, 4, 3, 2, 2] over 2 workers: LPT assigns 5->w0, 4->w1,
        // 3->w1 (load 4 < 5), 2->w0 (5 < 7), 2->w0 (tie 7/7 -> lowest
        // index) — final loads 9 and 7, the optimal makespan for this
        // vector (greedy LPT is optimal here; any split has a side >= 8,
        // and {5,2,2}/{4,3} achieves 9 vs the naive block split's 12).
        let ordered: Vec<usize> = (0..5).collect();
        let cost = [5.0, 4.0, 3.0, 2.0, 2.0];
        let queues = distribute_costed(&ordered, 2, Distribution::Lpt, &cost);
        assert_eq!(queues, vec![vec![0, 3, 4], vec![1, 2]]);
        let load = |q: &[usize]| q.iter().map(|&t| cost[t]).sum::<f64>();
        assert_eq!(load(&queues[0]), 9.0);
        assert_eq!(load(&queues[1]), 7.0);
    }

    #[test]
    fn lpt_beats_block_on_monotone_costs() {
        // Monotonically falling costs (the aerodrome archiving skew):
        // block gives the first worker all the heavy tasks; LPT's max bin
        // load must never exceed block's.
        let n = 40;
        let ordered: Vec<usize> = (0..n).collect();
        let cost: Vec<f64> = (0..n).map(|i| (n - i) as f64).collect();
        let max_load = |queues: &[Vec<usize>]| -> f64 {
            queues
                .iter()
                .map(|q| q.iter().map(|&t| cost[t]).sum::<f64>())
                .fold(0.0, f64::max)
        };
        for nworkers in [2, 3, 7] {
            let lpt = distribute_costed(&ordered, nworkers, Distribution::Lpt, &cost);
            let block = distribute_costed(&ordered, nworkers, Distribution::Block, &cost);
            assert!(
                max_load(&lpt) <= max_load(&block),
                "LPT {} > block {} at W={nworkers}",
                max_load(&lpt),
                max_load(&block)
            );
        }
    }

    #[test]
    fn lpt_is_a_cost_partition() {
        // LPT balances cost, not count, so it sits outside the
        // count-fairness loop above — but it must still be a partition,
        // and with unit costs (plain `distribute`) it degenerates to
        // exactly the cyclic round-robin assignment.
        testing::check("lpt partition", |rng| {
            let n = gen::task_count(rng);
            let nworkers = gen::worker_count(rng);
            let tasks = mk_tasks(rng, n);
            let ordered: Vec<usize> = order_tasks(&tasks, TaskOrder::Random(5));
            let cost = CostEstimate::from_tasks(&tasks);
            let queues = distribute_costed(&ordered, nworkers, Distribution::Lpt, cost.as_slice());
            prop_assert!(queues.len() == nworkers, "queue count");
            let mut count = vec![0usize; n];
            for q in &queues {
                for &t in q {
                    prop_assert!(t < n, "out-of-range index {t}");
                    count[t] += 1;
                }
            }
            prop_assert!(count.iter().all(|&c| c == 1), "not a partition: {count:?}");
            let unit = distribute(&ordered, nworkers, Distribution::Lpt);
            let cyclic = distribute(&ordered, nworkers, Distribution::Cyclic);
            prop_assert!(unit == cyclic, "unit-cost LPT must round-robin");
            Ok(())
        });
    }

    #[test]
    fn cost_descending_order_sorts_by_estimate() {
        let mut rng = Rng::new(17);
        let tasks = mk_tasks(&mut rng, 200);
        let cost = CostEstimate::from_tasks(&tasks);
        let idx = order_tasks(&tasks, TaskOrder::CostDescending);
        assert!(is_permutation(&idx, tasks.len()));
        for pair in idx.windows(2) {
            assert!(
                cost.get(pair[0]) >= cost.get(pair[1]),
                "costs out of order: {} then {}",
                cost.get(pair[0]),
                cost.get(pair[1])
            );
        }
        // The estimate weighs all three drivers, with obs dominating at
        // the calibrated weights (5e-3/obs vs 1e-6/byte vs 2e-4/cell).
        let t = Task {
            id: 0,
            bytes: 2_000_000,
            obs: 100,
            dem_cells: 500,
            chrono_key: 0,
            name: "t".into(),
        };
        assert!((CostEstimate::of(&t) - (2.0 + 0.5 + 0.1)).abs() < 1e-9);
    }

    #[test]
    fn from_manifest_matches_manifest_orderings() {
        let manifest = FileManifest {
            kind: DatasetKind::Monday,
            entries: vec![
                FileEntry { name: "d0h0.csv".into(), size: 100, day: 0, hour: 0, group: 0 },
                FileEntry { name: "d1h0.csv".into(), size: 300, day: 1, hour: 0, group: 0 },
                FileEntry { name: "d0h1.csv".into(), size: 200, day: 0, hour: 1, group: 0 },
            ],
        };
        let tasks = Task::from_manifest(&manifest);
        assert_eq!(tasks.len(), 3);
        assert_eq!(tasks[1].bytes, 300);
        assert_eq!(
            order_tasks(&tasks, TaskOrder::Chronological),
            manifest.chronological()
        );
        assert_eq!(
            order_tasks(&tasks, TaskOrder::LargestFirst),
            manifest.largest_first()
        );
    }
}
