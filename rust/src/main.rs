//! `emproc` CLI entrypoint — see `emproc help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(emproc::cli::run(&args));
}
