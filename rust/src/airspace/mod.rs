//! Airspace classes and synthetic aerodromes (§II scope / §III.B filter).
//!
//! The paper scopes to aircraft "within 8-10 nautical miles of an airport
//! surface in controlled airspace" and filters query boxes to Class B, C
//! and D airspace. Real airspace boundaries are FAA data; here each
//! synthetic aerodrome projects a cylinder of its class (B: 10 nm, C: 5 nm,
//! D: 4 nm — representative radii), and classification returns the most
//! restrictive class covering a point.

use crate::util::Rng;

/// Airspace class of interest (E/G collapsed into `Other`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum AirspaceClass {
    B,
    C,
    D,
    Other,
}

impl AirspaceClass {
    /// Representative surface-area radius (nm).
    pub fn radius_nm(self) -> f64 {
        match self {
            AirspaceClass::B => 10.0,
            AirspaceClass::C => 5.0,
            AirspaceClass::D => 4.0,
            AirspaceClass::Other => 0.0,
        }
    }

    /// Parse a one-letter class name.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.trim().to_ascii_uppercase().as_str() {
            "B" => AirspaceClass::B,
            "C" => AirspaceClass::C,
            "D" => AirspaceClass::D,
            "OTHER" | "E" | "G" => AirspaceClass::Other,
            _ => return None,
        })
    }
}

/// A synthetic aerodrome with a controlled-airspace cylinder.
#[derive(Debug, Clone)]
pub struct Aerodrome {
    /// Four-letter-style identifier (`SYN0`, `SYN1`, ...).
    pub id: String,
    /// Center latitude, degrees.
    pub lat: f64,
    /// Center longitude, degrees.
    pub lon: f64,
    /// Airspace class of the controlled cylinder.
    pub class: AirspaceClass,
}

/// The set of aerodromes forming the synthetic airspace map.
#[derive(Debug, Clone, Default)]
pub struct AirspaceMap {
    /// Every aerodrome on the map.
    pub aerodromes: Vec<Aerodrome>,
}

impl AirspaceMap {
    /// Most restrictive class whose cylinder covers the point.
    pub fn classify(&self, lat: f64, lon: f64) -> AirspaceClass {
        let mut best = AirspaceClass::Other;
        for a in &self.aerodromes {
            let c = crate::geometry::Circle {
                lat: a.lat,
                lon: a.lon,
                radius_nm: a.class.radius_nm(),
            };
            if c.contains(lat, lon) && a.class < best {
                best = a.class;
            }
        }
        best
    }

    /// Distance (nm, flat-earth small-angle) from a point to the nearest
    /// aerodrome, used by the query filter "within a desired... distance
    /// from aerodrome".
    pub fn nearest_aerodrome_nm(&self, lat: f64, lon: f64) -> f64 {
        self.aerodromes
            .iter()
            .map(|a| {
                let dy = (lat - a.lat) * 60.0;
                let dx = (lon - a.lon) * 60.0 * lat.to_radians().cos();
                (dx * dx + dy * dy).sqrt()
            })
            .fold(f64::INFINITY, f64::min)
    }
}

/// Generate `n` synthetic aerodromes over a CONUS-like region with a
/// B/C/D mix (few Bravos, many Deltas) and some metroplex clustering —
/// clustering is what makes circle unions overlap (Fig 1).
pub fn generate_aerodromes(rng: &mut Rng, n: usize) -> AirspaceMap {
    let mut aerodromes = Vec::with_capacity(n);
    let mut i = 0;
    while aerodromes.len() < n {
        let (lat, lon) = if !aerodromes.is_empty() && rng.f64() < 0.3 {
            // Satellite field near an existing one (metroplex).
            let k = rng.below(aerodromes.len());
            let base: &Aerodrome = &aerodromes[k];
            (
                base.lat + rng.normal_with(0.0, 0.15),
                base.lon + rng.normal_with(0.0, 0.2),
            )
        } else {
            (rng.uniform(26.0, 47.0), rng.uniform(-122.0, -68.0))
        };
        let r = rng.f64();
        let class = if r < 0.08 {
            AirspaceClass::B
        } else if r < 0.30 {
            AirspaceClass::C
        } else {
            AirspaceClass::D
        };
        aerodromes.push(Aerodrome { id: format!("SYN{i}"), lat, lon, class });
        i += 1;
    }
    AirspaceMap { aerodromes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_most_restrictive_wins() {
        let map = AirspaceMap {
            aerodromes: vec![
                Aerodrome { id: "D1".into(), lat: 42.0, lon: -71.0, class: AirspaceClass::D },
                Aerodrome { id: "B1".into(), lat: 42.02, lon: -71.02, class: AirspaceClass::B },
            ],
        };
        assert_eq!(map.classify(42.0, -71.0), AirspaceClass::B);
    }

    #[test]
    fn classify_outside_is_other() {
        let map = AirspaceMap {
            aerodromes: vec![Aerodrome {
                id: "D1".into(),
                lat: 42.0,
                lon: -71.0,
                class: AirspaceClass::D,
            }],
        };
        assert_eq!(map.classify(30.0, -100.0), AirspaceClass::Other);
    }

    #[test]
    fn nearest_distance_is_zero_at_field() {
        let map = AirspaceMap {
            aerodromes: vec![Aerodrome {
                id: "D1".into(),
                lat: 42.0,
                lon: -71.0,
                class: AirspaceClass::D,
            }],
        };
        assert!(map.nearest_aerodrome_nm(42.0, -71.0) < 1e-9);
        let d = map.nearest_aerodrome_nm(43.0, -71.0); // 60 nm north
        assert!((d - 60.0).abs() < 0.5, "{d}");
    }

    #[test]
    fn generator_mix_and_bounds() {
        let mut rng = Rng::new(7);
        let map = generate_aerodromes(&mut rng, 400);
        assert_eq!(map.aerodromes.len(), 400);
        let b = map.aerodromes.iter().filter(|a| a.class == AirspaceClass::B).count();
        let d = map.aerodromes.iter().filter(|a| a.class == AirspaceClass::D).count();
        assert!(b < d, "expected fewer Bravos ({b}) than Deltas ({d})");
        for a in &map.aerodromes {
            assert!((20.0..=50.0).contains(&a.lat), "lat {}", a.lat);
            assert!((-130.0..=-60.0).contains(&a.lon), "lon {}", a.lon);
        }
    }

    #[test]
    fn class_ordering_b_most_restrictive() {
        assert!(AirspaceClass::B < AirspaceClass::C);
        assert!(AirspaceClass::C < AirspaceClass::D);
        assert!(AirspaceClass::D < AirspaceClass::Other);
    }
}
