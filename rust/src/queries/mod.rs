//! §III.B: aerodrome query generation (the em-download-opensky pipeline).
//!
//! Chain (Figs 1-2): aerodromes → fixed-radius circles → rasterized union →
//! rectilinear polygons → rectangle decomposition → split large rectangles →
//! filter by airspace class and distance-to-aerodrome → DEM min/max per box
//! → MSL range from the desired AGL range → meridian time zone → load-
//! balancing group assignment → per-day query expansion.

use crate::airspace::{AirspaceClass, AirspaceMap};
use crate::dem::{Dem, FT_PER_M};
use crate::geometry::{CellGrid, Circle, Rect};

/// Pipeline parameters (paper defaults in `Default`).
#[derive(Debug, Clone)]
pub struct QueryGenConfig {
    /// Radius around each aerodrome (RTCA SC-228 terminal cylinder: 8 nm).
    pub radius_nm: f64,
    /// Raster cells per radius (resolution of the Fig 1 rasterization).
    pub cells_per_radius: usize,
    /// Max bounding-box side, degrees ("large rectangles are iteratively
    /// divided into smaller boxes").
    pub max_box_deg: f64,
    /// Keep boxes whose center lies in one of these classes.
    pub classes: Vec<AirspaceClass>,
    /// Drop boxes whose center is farther than this from any aerodrome.
    pub max_aerodrome_nm: f64,
    /// Desired AGL range (ft): paper default 5,100 ft AGL...
    pub agl_range_ft: f64,
    /// ...with a hard MSL ceiling of 12,500 ft.
    pub msl_ceiling_ft: f64,
    /// Number of load-balancing groups.
    pub groups: usize,
}

impl Default for QueryGenConfig {
    fn default() -> Self {
        QueryGenConfig {
            radius_nm: 8.0,
            cells_per_radius: 4,
            max_box_deg: 0.5,
            classes: vec![AirspaceClass::B, AirspaceClass::C, AirspaceClass::D],
            max_aerodrome_nm: 10.0,
            agl_range_ft: 5_100.0,
            msl_ceiling_ft: 12_500.0,
            groups: 16,
        }
    }
}

/// One query bounding box (before day expansion).
#[derive(Debug, Clone)]
pub struct QueryBox {
    /// Query bounding box.
    pub bbox: Rect,
    /// Airspace class the box was generated for.
    pub class: AirspaceClass,
    /// Elevation-derived MSL altitude range for the query, feet.
    pub msl_lo_ft: f64,
    /// Upper MSL altitude bound, feet.
    pub msl_hi_ft: f64,
    /// Meridian-based UTC offset, hours.
    pub tz_offset_h: i8,
    /// Load-balancing / storage group.
    pub group: u32,
}

/// One executable query (box × local day).
#[derive(Debug, Clone)]
pub struct Query {
    /// Index into the generated [`QueryBox`] list.
    pub box_idx: usize,
    /// Day index in the campaign (paper: first 14 days of each month,
    /// Jan 2019 – Feb 2020 = 196 days).
    pub day: u32,
    /// Load-balancing / storage group (copied from the box).
    pub group: u32,
}

/// Meridian-based time zone: each 15° of longitude is one hour.
pub fn meridian_tz(lon: f64) -> i8 {
    (lon / 15.0).round() as i8
}

/// Run the geometric pipeline over an airspace map.
pub fn generate_boxes(map: &AirspaceMap, dem: &Dem, cfg: &QueryGenConfig) -> Vec<QueryBox> {
    // 1. Circles around aerodromes of the requested classes.
    let circles: Vec<Circle> = map
        .aerodromes
        .iter()
        .filter(|a| cfg.classes.contains(&a.class))
        .map(|a| Circle { lat: a.lat, lon: a.lon, radius_nm: cfg.radius_nm })
        .collect();
    if circles.is_empty() {
        return Vec::new();
    }

    // 2-3. Rasterized union -> rectilinear polygons -> rectangles.
    let grid = CellGrid::for_radius(cfg.radius_nm, cfg.cells_per_radius);
    let cells = grid.rasterize_union(&circles);
    let comps = grid.components(&cells);

    // 4. Split large rectangles.
    let mut rects: Vec<Rect> = Vec::new();
    for comp in &comps {
        for r in &comp.rects {
            rects.extend(r.split_to_max_side(cfg.max_box_deg));
        }
    }

    // 5. Filter by airspace class + distance, 6. DEM -> MSL range,
    // 7. meridian time zone, 8. group assignment (round-robin over boxes
    // sorted by group key keeps groups near-equal for load balancing).
    let mut out = Vec::new();
    for r in rects {
        let (clat, clon) = r.center();
        let class = map.classify(clat, clon);
        if !cfg.classes.contains(&class) {
            continue;
        }
        if map.nearest_aerodrome_nm(clat, clon) > cfg.max_aerodrome_nm {
            continue;
        }
        let (elev_lo_m, elev_hi_m) = dem.bbox_min_max_m(&r);
        let msl_lo_ft = elev_lo_m * FT_PER_M; // ground at the lowest terrain
        let msl_hi_ft = (elev_hi_m * FT_PER_M + cfg.agl_range_ft).min(cfg.msl_ceiling_ft);
        out.push(QueryBox {
            bbox: r,
            class,
            msl_lo_ft,
            msl_hi_ft,
            tz_offset_h: meridian_tz(clon),
            group: 0, // assigned below
        });
    }
    for (i, q) in out.iter_mut().enumerate() {
        q.group = (i % cfg.groups) as u32;
    }
    out
}

/// Expand boxes over a day campaign (paper: 196 days -> 136,884 queries).
pub fn expand_days(boxes: &[QueryBox], days: u32) -> Vec<Query> {
    let mut out = Vec::with_capacity(boxes.len() * days as usize);
    for day in 0..days {
        for (box_idx, b) in boxes.iter().enumerate() {
            out.push(Query { box_idx, day, group: b.group });
        }
    }
    out
}

/// Render boxes as the CSV the download scripts would consume.
pub fn boxes_to_csv(boxes: &[QueryBox]) -> String {
    use std::fmt::Write as _;
    let mut s = String::from(
        "lat_lo,lat_hi,lon_lo,lon_hi,class,msl_lo_ft,msl_hi_ft,tz_offset_h,group\n",
    );
    for b in boxes {
        let _ = writeln!(
            s,
            "{:.4},{:.4},{:.4},{:.4},{:?},{:.0},{:.0},{},{}",
            b.bbox.lat_lo,
            b.bbox.lat_hi,
            b.bbox.lon_lo,
            b.bbox.lon_hi,
            b.class,
            b.msl_lo_ft,
            b.msl_hi_ft,
            b.tz_offset_h,
            b.group
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::airspace::generate_aerodromes;
    use crate::util::Rng;

    fn small_map() -> AirspaceMap {
        let mut rng = Rng::new(11);
        generate_aerodromes(&mut rng, 30)
    }

    #[test]
    fn pipeline_produces_boxes() {
        let boxes = generate_boxes(&small_map(), &Dem, &QueryGenConfig::default());
        assert!(!boxes.is_empty());
    }

    #[test]
    fn boxes_respect_max_side_and_ceiling() {
        let cfg = QueryGenConfig::default();
        for b in generate_boxes(&small_map(), &Dem, &cfg) {
            assert!(b.bbox.width() <= cfg.max_box_deg + 1e-9);
            assert!(b.bbox.height() <= cfg.max_box_deg + 1e-9);
            assert!(b.msl_hi_ft <= cfg.msl_ceiling_ft + 1e-9);
            assert!(b.msl_lo_ft <= b.msl_hi_ft);
        }
    }

    #[test]
    fn box_centers_are_in_controlled_airspace_near_aerodromes() {
        let map = small_map();
        let cfg = QueryGenConfig::default();
        for b in generate_boxes(&map, &Dem, &cfg) {
            let (clat, clon) = b.bbox.center();
            assert_ne!(map.classify(clat, clon), AirspaceClass::Other);
            assert!(map.nearest_aerodrome_nm(clat, clon) <= cfg.max_aerodrome_nm);
        }
    }

    #[test]
    fn tz_is_meridian_based() {
        assert_eq!(meridian_tz(-71.0), -5);
        assert_eq!(meridian_tz(-90.0), -6);
        assert_eq!(meridian_tz(-120.0), -8);
        assert_eq!(meridian_tz(0.0), 0);
    }

    #[test]
    fn day_expansion_counts() {
        let boxes = generate_boxes(&small_map(), &Dem, &QueryGenConfig::default());
        let queries = expand_days(&boxes, 196);
        assert_eq!(queries.len(), boxes.len() * 196);
    }

    #[test]
    fn groups_are_balanced() {
        let cfg = QueryGenConfig::default();
        let boxes = generate_boxes(&small_map(), &Dem, &cfg);
        let mut counts = vec![0usize; cfg.groups];
        for b in &boxes {
            counts[b.group as usize] += 1;
        }
        let (lo, hi) = (
            counts.iter().min().copied().unwrap_or(0),
            counts.iter().max().copied().unwrap_or(0),
        );
        assert!(hi - lo <= 1, "groups unbalanced: {counts:?}");
    }

    #[test]
    fn csv_has_one_line_per_box() {
        let boxes = generate_boxes(&small_map(), &Dem, &QueryGenConfig::default());
        let csv = boxes_to_csv(&boxes);
        assert_eq!(csv.lines().count(), boxes.len() + 1);
    }
}
