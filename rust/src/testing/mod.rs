//! Minimal property-testing harness (`proptest` is unavailable offline).
//!
//! A property is a closure over a seeded [`Rng`]; the harness runs it for a
//! configurable number of cases with distinct derived seeds and, on failure,
//! reports the failing case's seed so the exact input regenerates with
//! `EMPROC_PROP_SEED=<seed> EMPROC_PROP_CASES=1 cargo test <name>`.

use crate::util::Rng;

/// Number of cases per property (override with `EMPROC_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("EMPROC_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("EMPROC_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5EED_0F7E_57AA_11CE) // fixed default: reproducible CI
}

/// Run `prop` for [`default_cases`] seeded cases. `prop` returns
/// `Err(message)` (or panics) to fail; the harness decorates the failure
/// with the case seed.
pub fn check<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = default_cases();
    let base = base_seed();
    for case in 0..cases {
        let seed = base
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (EMPROC_PROP_SEED={base}, case seed {seed}): {msg}"
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Convenience generators for common property inputs.
pub mod gen {
    use crate::util::Rng;

    /// Vec of length in `[min_len, max_len]` with elements from `f`.
    pub fn vec_of<T>(
        rng: &mut Rng,
        min_len: usize,
        max_len: usize,
        mut f: impl FnMut(&mut Rng) -> T,
    ) -> Vec<T> {
        let len = min_len + rng.below(max_len - min_len + 1);
        (0..len).map(|_| f(rng)).collect()
    }

    /// Positive "file size" in bytes, log-uniform across ~5 decades —
    /// matches the heavy-tailed regimes the schedulers must handle.
    pub fn file_size(rng: &mut Rng) -> u64 {
        let exp = rng.uniform(3.0, 9.5); // 1 KB .. ~3 GB
        10f64.powf(exp) as u64
    }

    /// Task count that exercises edge cases (0, 1, exactly-divisible, prime).
    pub fn task_count(rng: &mut Rng) -> usize {
        const INTERESTING: [usize; 8] = [0, 1, 2, 7, 64, 100, 255, 1021];
        if rng.f64() < 0.5 {
            INTERESTING[rng.below(INTERESTING.len())]
        } else {
            rng.below(2000)
        }
    }

    /// Worker count >= 1.
    pub fn worker_count(rng: &mut Rng) -> usize {
        1 + rng.below(256)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", |rng| {
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsum' failed")]
    fn failing_property_panics_with_seed() {
        check("falsum", |rng| {
            let x = rng.f64();
            prop_assert!(x < 0.5, "got {x}");
            Ok(())
        });
    }

    #[test]
    fn generators_respect_bounds() {
        check("gen bounds", |rng| {
            let v = gen::vec_of(rng, 2, 10, |r| r.f64());
            prop_assert!((2..=10).contains(&v.len()), "len {}", v.len());
            let s = gen::file_size(rng);
            prop_assert!(s >= 1_000, "size {s}");
            Ok(())
        });
    }
}
