//! Batched input/output buffers for the track model.
//!
//! Stage-3 workers accumulate track segments, pack them into fixed-shape
//! padded rows (the AOT artifact has static shapes), and read back the
//! resampled outputs. Packing clamps / pads exactly the way the Python-side
//! oracle expects: invalid slots have `valid = 0`.

use crate::runtime::manifest::ArtifactManifest;
use anyhow::{bail, Result};

/// One track segment's observations, in coordinator-native form.
#[derive(Debug, Clone, Default)]
pub struct SegmentObs {
    /// Seconds (relative to segment start), ascending.
    pub t: Vec<f32>,
    /// Latitude, degrees.
    pub lat: Vec<f32>,
    /// Longitude, degrees.
    pub lon: Vec<f32>,
    /// MSL altitude, feet.
    pub alt: Vec<f32>,
}

impl SegmentObs {
    /// Number of observations.
    pub fn len(&self) -> usize {
        self.t.len()
    }

    /// True if the segment has no observations.
    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }
}

/// Flat, padded input buffers matching the artifact's ABI.
#[derive(Debug, Clone)]
pub struct TrackBatch {
    /// Batch rows (padded segment slots).
    pub b: usize,
    /// Padded observations per row.
    pub n: usize,
    /// Padded output grid points per row.
    pub m: usize,
    /// Pallas tile size the buffers are padded to.
    pub tile: usize,
    /// Observation times, seconds (`[B, N]`).
    pub obs_t: Vec<f32>,
    /// Observation latitudes, degrees (`[B, N]`).
    pub obs_lat: Vec<f32>,
    /// Observation longitudes, degrees (`[B, N]`).
    pub obs_lon: Vec<f32>,
    /// Observation altitudes, feet MSL (`[B, N]`).
    pub obs_alt: Vec<f32>,
    /// 1.0 where an observation is real, 0.0 padding (`[B, N]`).
    pub obs_valid: Vec<f32>,
    /// Output sample times, seconds (`[B, M]`).
    pub grid_t: Vec<f32>,
    /// Flattened DEM tile the batch samples AGL from.
    pub dem: Vec<f32>,
    /// `(lat0, lon0, dlat, dlon)`.
    pub dem_meta: [f32; 4],
    /// How many of the `b` rows carry real segments.
    pub used_rows: usize,
    /// Globally-unique version of the DEM contents; lets the runtime cache
    /// the device-resident DEM buffer across executes (stage-3 workers run
    /// many batches per archive against one tile).
    pub dem_version: u64,
}

/// Global DEM version counter (see [`TrackBatch::dem_version`]).
static DEM_VERSION: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

fn next_dem_version() -> u64 {
    DEM_VERSION.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
}

impl TrackBatch {
    /// Empty batch sized for `manifest`, with an all-zero DEM tile.
    pub fn empty(manifest: &ArtifactManifest) -> Self {
        let (b, n, m, tile) = (manifest.b, manifest.n, manifest.m, manifest.tile);
        TrackBatch {
            b,
            n,
            m,
            tile,
            obs_t: vec![0.0; b * n],
            obs_lat: vec![0.0; b * n],
            obs_lon: vec![0.0; b * n],
            obs_alt: vec![0.0; b * n],
            obs_valid: vec![0.0; b * n],
            grid_t: vec![0.0; b * m],
            dem: vec![0.0; tile * tile],
            dem_meta: [0.0, 0.0, 1.0, 1.0],
            used_rows: 0,
            dem_version: next_dem_version(),
        }
    }

    /// Install the shared DEM tile for this batch (row-major `tile x tile`
    /// metres) and its origin/spacing metadata.
    pub fn set_dem(&mut self, dem: &[f32], meta: [f32; 4]) -> Result<()> {
        if dem.len() != self.tile * self.tile {
            bail!(
                "dem tile has {} elements, artifact expects {}",
                dem.len(),
                self.tile * self.tile
            );
        }
        self.dem.copy_from_slice(dem);
        self.dem_meta = meta;
        self.dem_version = next_dem_version();
        Ok(())
    }

    /// Pack one segment into the next free row with a uniform output grid
    /// spanning the segment. Longer segments than `n` are truncated (the
    /// coordinator splits long segments upstream). Returns the row index, or
    /// `None` when the batch is full.
    pub fn push_segment(&mut self, seg: &SegmentObs) -> Option<usize> {
        if self.used_rows == self.b {
            return None;
        }
        let row = self.used_rows;
        let count = seg.len().min(self.n);
        let base = row * self.n;
        for i in 0..count {
            self.obs_t[base + i] = seg.t[i];
            self.obs_lat[base + i] = seg.lat[i];
            self.obs_lon[base + i] = seg.lon[i];
            self.obs_alt[base + i] = seg.alt[i];
            self.obs_valid[base + i] = 1.0;
        }
        // Uniform grid across the observed span (or degenerate zero grid).
        let (t0, t1) = if count >= 2 {
            (seg.t[0], seg.t[count - 1])
        } else {
            (0.0, 1.0)
        };
        let gbase = row * self.m;
        let denom = (self.m - 1).max(1) as f32;
        for j in 0..self.m {
            self.grid_t[gbase + j] = t0 + (t1 - t0) * j as f32 / denom;
        }
        self.used_rows += 1;
        Some(row)
    }

    /// Reset to an empty batch, preserving the DEM tile.
    pub fn clear_rows(&mut self) {
        self.obs_valid.iter_mut().for_each(|v| *v = 0.0);
        self.used_rows = 0;
    }

    /// Inputs in ABI order as `(flat_data, dims)` pairs.
    pub fn abi_inputs(&self) -> Vec<(&[f32], Vec<i64>)> {
        vec![
            (&self.obs_t[..], vec![self.b as i64, self.n as i64]),
            (&self.obs_lat[..], vec![self.b as i64, self.n as i64]),
            (&self.obs_lon[..], vec![self.b as i64, self.n as i64]),
            (&self.obs_alt[..], vec![self.b as i64, self.n as i64]),
            (&self.obs_valid[..], vec![self.b as i64, self.n as i64]),
            (&self.grid_t[..], vec![self.b as i64, self.m as i64]),
            (&self.dem[..], vec![self.tile as i64, self.tile as i64]),
            (&self.dem_meta[..], vec![4]),
        ]
    }
}

/// Model outputs, one `[B, M]` row-major buffer per field.
#[derive(Debug, Clone)]
pub struct TrackOutputs {
    /// Batch rows.
    pub b: usize,
    /// Grid points per row.
    pub m: usize,
    /// Interpolated latitudes, degrees.
    pub lat: Vec<f32>,
    /// Interpolated longitudes, degrees.
    pub lon: Vec<f32>,
    /// Interpolated altitudes, feet MSL.
    pub alt: Vec<f32>,
    /// Vertical rates, ft/min.
    pub vrate: Vec<f32>,
    /// Ground speeds, knots.
    pub gspeed: Vec<f32>,
    /// Above-ground-level altitudes, feet.
    pub agl: Vec<f32>,
    /// 1.0 where the grid point lies inside the segment's span.
    pub valid: Vec<f32>,
}

impl TrackOutputs {
    /// View of row `r` of an output field.
    pub fn row<'a>(&self, field: &'a [f32], r: usize) -> &'a [f32] {
        &field[r * self.m..(r + 1) * self.m]
    }

    /// True if row `r` produced valid resampled output.
    pub fn row_valid(&self, r: usize) -> bool {
        self.valid[r * self.m] > 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> ArtifactManifest {
        ArtifactManifest::parse(
            "name=track_model\nb=2\nn=4\nm=3\ntile=2\n\
             inputs=obs_t,obs_lat,obs_lon,obs_alt,obs_valid,grid_t,dem,dem_meta\n\
             outputs=lat,lon,alt,vrate,gspeed,agl,valid\n",
        )
        .unwrap()
    }

    fn seg(n: usize) -> SegmentObs {
        SegmentObs {
            t: (0..n).map(|i| i as f32 * 10.0).collect(),
            lat: vec![40.0; n],
            lon: vec![-71.0; n],
            alt: vec![1000.0; n],
        }
    }

    #[test]
    fn push_fills_rows_then_rejects() {
        let mut b = TrackBatch::empty(&tiny_manifest());
        assert_eq!(b.push_segment(&seg(3)), Some(0));
        assert_eq!(b.push_segment(&seg(2)), Some(1));
        assert_eq!(b.push_segment(&seg(2)), None);
        assert_eq!(b.used_rows, 2);
    }

    #[test]
    fn pads_and_masks() {
        let mut b = TrackBatch::empty(&tiny_manifest());
        b.push_segment(&seg(3)).unwrap();
        assert_eq!(&b.obs_valid[0..4], &[1.0, 1.0, 1.0, 0.0]);
        assert_eq!(b.obs_t[2], 20.0);
        assert_eq!(b.obs_t[3], 0.0);
    }

    #[test]
    fn truncates_long_segments() {
        let mut b = TrackBatch::empty(&tiny_manifest());
        b.push_segment(&seg(10)).unwrap();
        assert_eq!(&b.obs_valid[0..4], &[1.0; 4]);
        assert_eq!(b.obs_t[3], 30.0);
    }

    #[test]
    fn grid_spans_segment() {
        let mut b = TrackBatch::empty(&tiny_manifest());
        b.push_segment(&seg(3)).unwrap();
        assert_eq!(&b.grid_t[0..3], &[0.0, 10.0, 20.0]);
    }

    #[test]
    fn clear_preserves_dem() {
        let mut b = TrackBatch::empty(&tiny_manifest());
        b.set_dem(&[1.0, 2.0, 3.0, 4.0], [40.0, -71.0, 0.1, 0.1]).unwrap();
        b.push_segment(&seg(3)).unwrap();
        b.clear_rows();
        assert_eq!(b.used_rows, 0);
        assert!(b.obs_valid.iter().all(|&v| v == 0.0));
        assert_eq!(b.dem, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn dem_size_mismatch_errors() {
        let mut b = TrackBatch::empty(&tiny_manifest());
        assert!(b.set_dem(&[1.0; 3], [0.0; 4]).is_err());
    }

    #[test]
    fn abi_order_matches_manifest() {
        let man = tiny_manifest();
        let b = TrackBatch::empty(&man);
        let abi = b.abi_inputs();
        assert_eq!(abi.len(), man.inputs.len());
        for (i, (data, dims)) in abi.iter().enumerate() {
            assert_eq!(data.len(), man.input_len(i).unwrap(), "input {i}");
            assert_eq!(*dims, man.input_dims(i).unwrap(), "input {i}");
        }
    }
}
