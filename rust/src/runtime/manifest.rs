//! Plain-text `key=value` manifest describing an AOT artifact's ABI.
//!
//! Written by `python/compile/aot.py` next to the HLO text. Hand-rolled
//! parser because `serde` is unavailable in the offline build.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Shapes and input/output ordering of one compiled model artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactManifest {
    /// Model name (`track_model`).
    pub name: String,
    /// Tracks per batch.
    pub b: usize,
    /// Padded observations per track.
    pub n: usize,
    /// Output grid points per track.
    pub m: usize,
    /// DEM tile side length.
    pub tile: usize,
    /// Parameter names in ABI order.
    pub inputs: Vec<String>,
    /// Tuple-output names in ABI order.
    pub outputs: Vec<String>,
}

impl ArtifactManifest {
    /// Parse manifest text (`key=value` lines; `#` comments allowed).
    pub fn parse(text: &str) -> Result<Self> {
        let mut kv = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("manifest line {}: missing '='", lineno + 1))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<String> {
            kv.get(k)
                .cloned()
                .with_context(|| format!("manifest missing key '{k}'"))
        };
        let get_usize = |k: &str| -> Result<usize> {
            get(k)?
                .parse::<usize>()
                .with_context(|| format!("manifest key '{k}' is not an integer"))
        };
        let m = ArtifactManifest {
            name: get("name")?,
            b: get_usize("b")?,
            n: get_usize("n")?,
            m: get_usize("m")?,
            tile: get_usize("tile")?,
            inputs: get("inputs")?.split(',').map(str::to_string).collect(),
            outputs: get("outputs")?.split(',').map(str::to_string).collect(),
        };
        if m.b == 0 || m.n == 0 || m.m == 0 || m.tile == 0 {
            bail!("manifest has zero-sized dimension: {m:?}");
        }
        Ok(m)
    }

    /// Load and parse from a file path.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    /// Expected flat element count for the input at ABI position `i`;
    /// an unknown input name is a corrupt/foreign manifest, not a bug.
    pub fn input_len(&self, i: usize) -> Result<usize> {
        Ok(match self.inputs[i].as_str() {
            "obs_t" | "obs_lat" | "obs_lon" | "obs_alt" | "obs_valid" => self.b * self.n,
            "grid_t" => self.b * self.m,
            "dem" => self.tile * self.tile,
            "dem_meta" => 4,
            other => bail!("unknown input '{other}' in manifest"),
        })
    }

    /// Expected dims for the input at ABI position `i`.
    pub fn input_dims(&self, i: usize) -> Result<Vec<i64>> {
        Ok(match self.inputs[i].as_str() {
            "obs_t" | "obs_lat" | "obs_lon" | "obs_alt" | "obs_valid" => {
                vec![self.b as i64, self.n as i64]
            }
            "grid_t" => vec![self.b as i64, self.m as i64],
            "dem" => vec![self.tile as i64, self.tile as i64],
            "dem_meta" => vec![4],
            other => bail!("unknown input '{other}' in manifest"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "name=track_model\nb=16\nn=128\nm=64\ntile=64\n\
        inputs=obs_t,obs_lat,obs_lon,obs_alt,obs_valid,grid_t,dem,dem_meta\n\
        outputs=lat,lon,alt,vrate,gspeed,agl,valid\ndtype=f32\nreturn_tuple=1\n";

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "track_model");
        assert_eq!((m.b, m.n, m.m, m.tile), (16, 128, 64, 64));
        assert_eq!(m.inputs.len(), 8);
        assert_eq!(m.outputs.len(), 7);
    }

    #[test]
    fn input_lens_match_shapes() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.input_len(0), 16 * 128); // obs_t
        assert_eq!(m.input_len(5), 16 * 64); // grid_t
        assert_eq!(m.input_len(6), 64 * 64); // dem
        assert_eq!(m.input_len(7), 4); // dem_meta
        assert_eq!(m.input_dims(6), vec![64, 64]);
    }

    #[test]
    fn missing_key_is_error() {
        assert!(ArtifactManifest::parse("name=x\nb=1\n").is_err());
    }

    #[test]
    fn non_integer_dim_is_error() {
        let bad = SAMPLE.replace("b=16", "b=sixteen");
        assert!(ArtifactManifest::parse(&bad).is_err());
    }

    #[test]
    fn zero_dim_is_error() {
        let bad = SAMPLE.replace("b=16", "b=0");
        assert!(ArtifactManifest::parse(&bad).is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let text = format!("# header\n\n{SAMPLE}");
        assert!(ArtifactManifest::parse(&text).is_ok());
    }
}
