//! L3 ⇄ L2 bridge: load and execute the AOT-compiled track model via PJRT.
//!
//! `make artifacts` (build time, the only place Python runs) lowers the JAX
//! track model — whose hot spot is the Pallas interpolation/AGL kernels — to
//! HLO *text* plus a `key=value` manifest. At run time this module:
//!
//! 1. parses the manifest for the batch shapes and ABI order,
//! 2. parses the HLO text into an `HloModuleProto` (text, not a
//!    serialized proto: xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit ids),
//! 3. compiles it once on the PJRT CPU client,
//! 4. executes it from the stage-3 worker hot path with zero Python.
//!
//! The offline build has no `xla` crate, so [`model`] is backed by
//! [`xla_stub`]: an API-compatible native CPU implementation of the track
//! model's reference semantics, pinned against the Python oracle by the
//! checked-in golden file (`rust/tests/runtime_golden.rs`).

/// Flat, padded input/output buffers matching the artifact ABI.
pub mod batch;
/// Parsed artifact manifest (shapes, dtypes, input order).
pub mod manifest;
/// The track model: artifact loading and batched execution.
pub mod model;
/// Native CPU stand-in for PJRT with the model's reference semantics.
pub mod xla_stub;

pub use batch::{TrackBatch, TrackOutputs};
pub use manifest::ArtifactManifest;
pub use model::TrackModel;

/// Default artifact directory, relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
