//! Native CPU stand-in for the `xla` crate's PJRT surface.
//!
//! The offline build environment has no `xla`/`xla_extension` crate, so
//! [`crate::runtime::model`] aliases this module as `xla` and everything
//! compiles with zero external dependencies. The API mirrors the subset of
//! xla-rs the runtime uses (client, compile, device buffers, execute,
//! literals); a build that does have the real crate only needs to switch
//! the alias back.
//!
//! Instead of interpreting HLO, [`PjRtLoadedExecutable::execute_b`]
//! evaluates the track model's *reference semantics* natively in `f32` —
//! a line-for-line port of `python/compile/kernels/ref.py` (linear
//! resampling onto the per-row grid, central-difference rates, and
//! border-clamped bilinear AGL). The checked-in
//! `artifacts/golden_track_model.txt` pins these semantics: the
//! `runtime_golden` integration test feeds the Python oracle's inputs
//! through this path and requires oracle-level agreement, so any drift
//! between the artifact model and this fallback is caught by `cargo test`.
//! Shapes are inferred from the uploaded buffer dims, exactly as the real
//! PJRT executable would see them.

use anyhow::{bail, Context, Result};

const BIG_T: f32 = 1.0e9;
const EPS_T: f32 = 1.0e-6;
const NM_PER_DEG: f32 = 60.0;
const FT_PER_M: f32 = 3.28084;

/// Parsed (but uninterpreted) HLO module text.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    /// Retained for diagnostics only; the native path executes the
    /// reference semantics, not this text.
    pub text_len: usize,
}

impl HloModuleProto {
    /// Read an HLO text artifact. The content is validated to be non-empty
    /// and is otherwise carried as provenance.
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading HLO text {path}"))?;
        if text.trim().is_empty() {
            bail!("HLO text {path} is empty");
        }
        Ok(HloModuleProto { text_len: text.len() })
    }
}

/// Computation handle (mirrors `xla::XlaComputation`).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    text_len: usize,
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        XlaComputation { text_len: proto.text_len }
    }
}

/// Host/device value: a flat f32 array with dims, or a tuple of them.
#[derive(Debug, Clone)]
pub enum Literal {
    /// Row-major f32 array.
    Array { values: Vec<f32>, dims: Vec<usize> },
    /// Tuple of literals (the model's 7-field output).
    Tuple(Vec<Literal>),
}

/// Element types downloadable from a [`Literal`].
pub trait NativeType: Copy {
    /// Convert from the stub's single storage type.
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

impl Literal {
    /// Tuple fields, consuming the literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            Literal::Array { .. } => bail!("literal is not a tuple"),
        }
    }

    /// Flat element download.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { values, .. } => {
                Ok(values.iter().map(|&v| T::from_f32(v)).collect())
            }
            Literal::Tuple(_) => bail!("literal is a tuple, not an array"),
        }
    }
}

/// Device-resident buffer (mirrors `xla::PjRtBuffer`).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    data: Literal,
}

impl AsRef<PjRtBuffer> for PjRtBuffer {
    fn as_ref(&self) -> &PjRtBuffer {
        self
    }
}

impl PjRtBuffer {
    /// Download to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.data.clone())
    }

    fn array(&self) -> Result<(&[f32], &[usize])> {
        match &self.data {
            Literal::Array { values, dims } => Ok((values, dims)),
            Literal::Tuple(_) => bail!("argument buffer holds a tuple"),
        }
    }
}

/// CPU client (mirrors `xla::PjRtClient`).
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    /// The native CPU "device".
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    /// Upload a host array.
    pub fn buffer_from_host_buffer(
        &self,
        data: &[f32],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let want: usize = dims.iter().product();
        if data.len() != want {
            bail!("buffer has {} elements, dims {:?} want {want}", data.len(), dims);
        }
        Ok(PjRtBuffer {
            data: Literal::Array { values: data.to_vec(), dims: dims.to_vec() },
        })
    }

    /// "Compile" the computation: the native path has nothing to lower,
    /// so this only records the module for diagnostics.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { _hlo_text_len: comp.text_len })
    }
}

/// Loaded executable (mirrors `xla::PjRtLoadedExecutable`).
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    _hlo_text_len: usize,
}

impl PjRtLoadedExecutable {
    /// Execute on buffer arguments in the track-model ABI order
    /// (`obs_t, obs_lat, obs_lon, obs_alt, obs_valid, grid_t, dem,
    /// dem_meta`), returning `[[tuple]]` like PJRT's
    /// per-device/per-output nesting.
    pub fn execute_b<T: AsRef<PjRtBuffer>>(&self, args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        if args.len() != 8 {
            bail!("track model expects 8 inputs, got {}", args.len());
        }
        let arrays: Vec<(&[f32], &[usize])> = args
            .iter()
            .map(|a| a.as_ref().array())
            .collect::<Result<_>>()?;
        let (b, n) = match arrays[0].1 {
            [b, n] => (*b, *n),
            other => bail!("obs_t dims {other:?}, want [b, n]"),
        };
        let m = match arrays[5].1 {
            [gb, m] if *gb == b => *m,
            other => bail!("grid_t dims {other:?}, want [{b}, m]"),
        };
        let tile = match arrays[6].1 {
            [th, tw] if th == tw => *th,
            other => bail!("dem dims {other:?}, want square"),
        };
        if m < 2 || tile < 2 {
            bail!("degenerate shapes: m={m} tile={tile}");
        }
        for (i, (values, _)) in arrays.iter().enumerate().take(5) {
            if values.len() != b * n {
                bail!("input {i} has {} elements, want {}", values.len(), b * n);
            }
        }
        if arrays[7].0.len() != 4 {
            bail!("dem_meta has {} elements, want 4", arrays[7].0.len());
        }
        let meta: [f32; 4] = [arrays[7].0[0], arrays[7].0[1], arrays[7].0[2], arrays[7].0[3]];

        let mut out: [Vec<f32>; 7] = std::array::from_fn(|_| Vec::with_capacity(b * m));
        for row in 0..b {
            let s = row * n;
            let g = row * m;
            let fields = interp_row(
                &arrays[0].0[s..s + n],
                &arrays[1].0[s..s + n],
                &arrays[2].0[s..s + n],
                &arrays[3].0[s..s + n],
                &arrays[4].0[s..s + n],
                &arrays[5].0[g..g + m],
                arrays[6].0,
                tile,
                meta,
            );
            for (dst, src) in out.iter_mut().zip(fields) {
                dst.extend(src);
            }
        }
        let parts: Vec<Literal> = out
            .into_iter()
            .map(|values| Literal::Array { values, dims: vec![b, m] })
            .collect();
        Ok(vec![vec![PjRtBuffer { data: Literal::Tuple(parts) }]])
    }
}

/// Resample one padded track row onto its grid and compute rates + AGL —
/// the `f32` port of `ref._interp_one` + `ref.agl_tracks_ref`. Returns
/// `[lat, lon, alt, vrate, gspeed, agl, valid]`, each of length `m`.
#[allow(clippy::too_many_arguments)]
fn interp_row(
    t: &[f32],
    lat: &[f32],
    lon: &[f32],
    alt: &[f32],
    valid: &[f32],
    grid: &[f32],
    dem: &[f32],
    tile: usize,
    meta: [f32; 4],
) -> [Vec<f32>; 7] {
    let n = t.len();
    let m = grid.len();
    let n_valid: f32 = valid.iter().sum();
    let last = (n_valid - 1.0).max(0.0);
    let ovalid: f32 = if n_valid >= 2.0 { 1.0 } else { 0.0 };

    let mut o_lat = vec![0.0f32; m];
    let mut o_lon = vec![0.0f32; m];
    let mut o_alt = vec![0.0f32; m];
    for j in 0..m {
        // Rank of the grid point among valid observation times.
        let mut cnt = 0.0f32;
        for i in 0..n {
            let t_eff = if valid[i] > 0.5 { t[i] } else { BIG_T };
            if t_eff <= grid[j] {
                cnt += 1.0;
            }
        }
        let idx_lo = (cnt - 1.0).clamp(0.0, last) as usize;
        let idx_hi = cnt.clamp(0.0, last) as usize;
        let t_lo = t[idx_lo];
        let t_hi = t[idx_hi];
        let dt = t_hi - t_lo;
        let frac = if dt > EPS_T {
            ((grid[j] - t_lo) / dt).clamp(0.0, 1.0)
        } else {
            0.0
        };
        o_lat[j] = lat[idx_lo] + frac * (lat[idx_hi] - lat[idx_lo]);
        o_lon[j] = lon[idx_lo] + frac * (lon[idx_hi] - lon[idx_lo]);
        o_alt[j] = alt[idx_lo] + frac * (alt[idx_hi] - alt[idx_lo]);
    }

    // Central differences on the uniform grid (one-sided at the ends).
    let gdt = (grid[1] - grid[0]).max(EPS_T);
    let cdiff = |x: &[f32], j: usize| -> f32 {
        let next = x[(j + 1).min(m - 1)];
        let prev = x[j.saturating_sub(1)];
        let span: f32 = if j == 0 || j == m - 1 { 1.0 } else { 2.0 };
        (next - prev) / (span * gdt)
    };

    let mut out_lat = vec![0.0f32; m];
    let mut out_lon = vec![0.0f32; m];
    let mut out_alt = vec![0.0f32; m];
    let mut vrate = vec![0.0f32; m];
    let mut gspeed = vec![0.0f32; m];
    let mut agl = vec![0.0f32; m];
    let valid_out = vec![ovalid; m];
    for j in 0..m {
        vrate[j] = cdiff(&o_alt, j) * 60.0 * ovalid;
        let dlat = cdiff(&o_lat, j) * NM_PER_DEG;
        let dlon = cdiff(&o_lon, j) * NM_PER_DEG * o_lat[j].to_radians().cos();
        gspeed[j] = (dlat * dlat + dlon * dlon).sqrt() * 3600.0 * ovalid;
        out_lat[j] = o_lat[j] * ovalid;
        out_lon[j] = o_lon[j] * ovalid;
        out_alt[j] = o_alt[j] * ovalid;
        let elev_ft = bilinear(dem, tile, meta, out_lat[j], out_lon[j]) * FT_PER_M;
        agl[j] = (out_alt[j] - elev_ft) * ovalid;
    }
    [out_lat, out_lon, out_alt, vrate, gspeed, agl, valid_out]
}

/// Border-clamped bilinear DEM sample in metres (`ref._bilinear_one`).
fn bilinear(dem: &[f32], tile: usize, meta: [f32; 4], lat: f32, lon: f32) -> f32 {
    let hi = tile as f32 - 1.000_001;
    let ri = ((lat - meta[0]) / meta[2]).clamp(0.0, hi);
    let ci = ((lon - meta[1]) / meta[3]).clamp(0.0, hi);
    let r0 = ri.floor() as usize;
    let c0 = ci.floor() as usize;
    let fr = ri - r0 as f32;
    let fc = ci - c0 as f32;
    let at = |r: usize, c: usize| dem[r * tile + c];
    let top = at(r0, c0) * (1.0 - fc) + at(r0, c0 + 1) * fc;
    let bot = at(r0 + 1, c0) * (1.0 - fc) + at(r0 + 1, c0 + 1) * fc;
    top * (1.0 - fr) + bot * fr
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upload(client: &PjRtClient, values: Vec<f32>, dims: &[usize]) -> PjRtBuffer {
        client.buffer_from_host_buffer(&values, dims, None).unwrap()
    }

    /// Run a tiny 1-row model through the full stub API surface.
    fn run_tiny(valid: Vec<f32>) -> Vec<Vec<f32>> {
        let (b, n, m, tile) = (1usize, 4usize, 5usize, 2usize);
        let client = PjRtClient::cpu().unwrap();
        let exe = client
            .compile(&XlaComputation::from_proto(&HloModuleProto { text_len: 1 }))
            .unwrap();
        let t = vec![0.0, 10.0, 20.0, 30.0];
        let lat = vec![40.0, 40.0, 40.0, 40.0];
        let lon = vec![-71.0, -71.0, -71.0, -71.0];
        let alt = vec![1000.0, 1100.0, 1200.0, 1300.0];
        let grid: Vec<f32> = (0..m).map(|j| j as f32 * 30.0 / (m - 1) as f32).collect();
        let dem = vec![100.0, 100.0, 100.0, 100.0];
        let meta = vec![39.0f32, -72.0, 1.0, 1.0];
        let bufs = vec![
            upload(&client, t, &[b, n]),
            upload(&client, lat, &[b, n]),
            upload(&client, lon, &[b, n]),
            upload(&client, alt, &[b, n]),
            upload(&client, valid, &[b, n]),
            upload(&client, grid, &[b, m]),
            upload(&client, dem, &[tile, tile]),
            upload(&client, meta, &[4]),
        ];
        let refs: Vec<&PjRtBuffer> = bufs.iter().collect();
        let result = exe.execute_b::<&PjRtBuffer>(&refs).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        result
            .to_tuple()
            .unwrap()
            .iter()
            .map(|p| p.to_vec::<f32>().unwrap())
            .collect()
    }

    #[test]
    fn linear_track_resamples_exactly() {
        let outs = run_tiny(vec![1.0; 4]);
        let (alt, vrate, agl, valid) = (&outs[2], &outs[3], &outs[5], &outs[6]);
        assert!(valid.iter().all(|&v| v == 1.0));
        // Altitude is linear 1000..1300 over t=0..30; grid is uniform.
        for (j, &a) in alt.iter().enumerate() {
            let want = 1000.0 + 300.0 * j as f32 / 4.0;
            assert!((a - want).abs() < 1e-2, "alt[{j}] {a} vs {want}");
        }
        // 10 ft/s climb = 600 ft/min everywhere on a linear profile.
        for &v in vrate {
            assert!((v - 600.0).abs() < 1.0, "vrate {v}");
        }
        // Flat 100 m DEM: AGL = alt - 328.084.
        for (j, &a) in agl.iter().enumerate() {
            let want = alt[j] - 100.0 * FT_PER_M;
            assert!((a - want).abs() < 0.1, "agl[{j}] {a} vs {want}");
        }
    }

    #[test]
    fn under_two_valid_observations_masks_row() {
        let outs = run_tiny(vec![1.0, 0.0, 0.0, 0.0]);
        for field in &outs {
            assert!(field.iter().all(|&v| v == 0.0), "row not masked: {field:?}");
        }
    }

    #[test]
    fn bilinear_interpolates_and_clamps() {
        let dem = vec![0.0, 10.0, 20.0, 30.0]; // 2x2
        let meta = [0.0f32, 0.0, 1.0, 1.0];
        // Centre of the cell: mean of the four corners.
        let mid = bilinear(&dem, 2, meta, 0.5, 0.5);
        assert!((mid - 15.0).abs() < 1e-4, "{mid}");
        // Far outside: clamps to the nearest corner.
        let far = bilinear(&dem, 2, meta, -100.0, -100.0);
        assert!((far - 0.0).abs() < 1e-4, "{far}");
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.buffer_from_host_buffer(&[1.0, 2.0], &[3], None).is_err());
        let exe = client
            .compile(&XlaComputation::from_proto(&HloModuleProto { text_len: 1 }))
            .unwrap();
        let one = upload(&client, vec![0.0], &[1, 1]);
        let refs: Vec<&PjRtBuffer> = vec![&one; 3];
        assert!(exe.execute_b::<&PjRtBuffer>(&refs).is_err());
    }
}
