//! Compiled track model: PJRT client + executable + the execute hot path.

use crate::runtime::batch::{TrackBatch, TrackOutputs};
use crate::runtime::manifest::ArtifactManifest;
// The offline toolchain has no real `xla` crate; the native stub mirrors
// its API (swap this alias for `use xla;` on a PJRT-enabled build).
use crate::runtime::xla_stub as xla;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// A loaded, compiled track-model artifact bound to a PJRT CPU client.
///
/// Compilation happens once (at load); [`TrackModel::execute`] is the only
/// thing stage-3 workers call on the hot path. The executable is not
/// `Sync`-shared across threads — each worker thread loads its own
/// `TrackModel` (compilation is cheap relative to the workload and this
/// mirrors the paper's process-per-slot EPPAC placement, where every
/// triples-mode process owns its resources).
pub struct TrackModel {
    manifest: ArtifactManifest,
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    /// Device-resident DEM tile + meta, keyed by the batch's dem_version
    /// (§Perf: avoids re-uploading the 16 KB tile on every execute).
    dem_cache: Option<(u64, xla::PjRtBuffer, xla::PjRtBuffer)>,
    /// Cumulative time spent inside PJRT execute (for §Perf accounting).
    exec_time: Duration,
    exec_calls: u64,
}

impl TrackModel {
    /// Load `track_model.hlo.txt` + `track_model.manifest` from `dir` and
    /// compile on a fresh PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let hlo = dir.join("track_model.hlo.txt");
        let man = dir.join("track_model.manifest");
        Self::load_paths(&hlo, &man)
    }

    /// Load from explicit paths.
    pub fn load_paths(hlo: &Path, manifest_path: &Path) -> Result<Self> {
        if !hlo.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                hlo.display()
            );
        }
        let manifest = ArtifactManifest::load(manifest_path)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().context("non-utf8 artifact path")?,
        )
        .context("parsing HLO text")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        Ok(TrackModel {
            manifest,
            client,
            exe,
            dem_cache: None,
            exec_time: Duration::ZERO,
            exec_calls: 0,
        })
    }

    /// Locate the artifact dir: `$EMPROC_ARTIFACTS`, else `artifacts/`
    /// relative to the current dir, else relative to the crate root.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("EMPROC_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let cwd = PathBuf::from("artifacts");
        if cwd.join("track_model.hlo.txt").exists() {
            return cwd;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// The artifact's manifest (shapes, ABI).
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Execute one batch. Validates buffer sizes against the manifest,
    /// uploads the eight inputs, runs the executable, and unpacks the
    /// 7-tuple into [`TrackOutputs`].
    pub fn execute(&mut self, batch: &TrackBatch) -> Result<TrackOutputs> {
        let man = &self.manifest;
        if batch.b != man.b || batch.n != man.n || batch.m != man.m || batch.tile != man.tile
        {
            bail!(
                "batch shape ({},{},{},{}) != artifact shape ({},{},{},{})",
                batch.b, batch.n, batch.m, batch.tile, man.b, man.n, man.m, man.tile
            );
        }
        let start = Instant::now();
        // Upload the per-batch inputs as device buffers directly (skips
        // the Literal intermediate); reuse the cached DEM buffers when the
        // tile is unchanged (stage-3 runs many batches per archive).
        let abi = batch.abi_inputs();
        let mut buffers: Vec<xla::PjRtBuffer> = Vec::with_capacity(6);
        for (i, (data, dims)) in abi.iter().enumerate().take(6) {
            let want = man.input_len(i)?;
            if data.len() != want {
                bail!("input {} has {} elements, want {want}", man.inputs[i], data.len());
            }
            let udims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
            buffers.push(
                self.client
                    .buffer_from_host_buffer(data, &udims, None)
                    .with_context(|| format!("uploading input {}", man.inputs[i]))?,
            );
        }
        if self
            .dem_cache
            .as_ref()
            .map(|(v, _, _)| *v != batch.dem_version)
            .unwrap_or(true)
        {
            let ddims: Vec<usize> = abi[6].1.iter().map(|&d| d as usize).collect();
            let mdims: Vec<usize> = abi[7].1.iter().map(|&d| d as usize).collect();
            let dem = self
                .client
                .buffer_from_host_buffer(abi[6].0, &ddims, None)
                .context("uploading dem")?;
            let meta = self
                .client
                .buffer_from_host_buffer(abi[7].0, &mdims, None)
                .context("uploading dem_meta")?;
            self.dem_cache = Some((batch.dem_version, dem, meta));
        }
        let (_, dem_buf, meta_buf) =
            self.dem_cache.as_ref().context("dem cache populated above")?;
        let args: Vec<&xla::PjRtBuffer> = buffers.iter().chain([dem_buf, meta_buf]).collect();
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .context("PJRT execute")?[0][0]
            .to_literal_sync()
            .context("downloading result")?;
        let parts = result.to_tuple().context("unpacking output tuple")?;
        if parts.len() != man.outputs.len() {
            bail!(
                "artifact returned {} outputs, manifest says {}",
                parts.len(),
                man.outputs.len()
            );
        }
        let mut fields: Vec<Vec<f32>> = Vec::with_capacity(parts.len());
        for (part, name) in parts.iter().zip(&man.outputs) {
            let v = part
                .to_vec::<f32>()
                .with_context(|| format!("downloading output {name}"))?;
            if v.len() != man.b * man.m {
                bail!("output {name} has {} elements, want {}", v.len(), man.b * man.m);
            }
            fields.push(v);
        }
        self.exec_time += start.elapsed();
        self.exec_calls += 1;
        let mut it = fields.into_iter();
        let mut take = |what: &str| {
            it.next().with_context(|| format!("artifact outputs missing {what}"))
        };
        Ok(TrackOutputs {
            b: man.b,
            m: man.m,
            lat: take("lat")?,
            lon: take("lon")?,
            alt: take("alt")?,
            vrate: take("vrate")?,
            gspeed: take("gspeed")?,
            agl: take("agl")?,
            valid: take("valid")?,
        })
    }

    /// `(calls, total_time)` spent inside PJRT execute so far.
    pub fn exec_stats(&self) -> (u64, Duration) {
        (self.exec_calls, self.exec_time)
    }
}
