//! Triples-mode job launch (§II.C): configuration + LLSC allocation rules.
//!
//! Triples-mode is governed by three parameters — requested compute nodes,
//! processes per node (NPPN), and threads per process — with explicit
//! process placement (EPPAC) and *exclusive* node allocation. The rules
//! encoded here are exactly the paper's:
//!
//! * xeon64c nodes have **64 slots** (cores), 3 GB memory per slot;
//! * NPPN should be **≤ 32 and a multiple of 8**;
//! * exclusive mode charges `nodes × 64 × slots_per_job` against the user's
//!   core allocation (4096 default at benchmark time; 8192 by publication —
//!   the §V follow-up). Requesting 2 slots/job doubles the per-process
//!   memory to 6 GB but halves the usable processes: "2048 cores with 2
//!   slots per core correspond to the maximum allocation of 4096 cores";
//! * at most 64 physical nodes per job.
//!
//! This reproduces the feasibility pattern of Tables I-II: every populated
//! cell satisfies these rules and every "-" cell violates them.

use anyhow::{bail, Result};

/// Slots (cores) per xeon64c node.
pub const SLOTS_PER_NODE: usize = 64;
/// Memory per slot, GB.
pub const GB_PER_SLOT: f64 = 3.0;
/// Default user core allocation at benchmark time (§II.C).
pub const DEFAULT_ALLOCATION: usize = 4096;
/// Upgraded allocation used by the §V follow-up.
pub const UPGRADED_ALLOCATION: usize = 8192;
/// Physical node ceiling per job.
pub const MAX_NODES: usize = 64;

/// A triples-mode launch configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TriplesConfig {
    /// Requested compute nodes.
    pub nodes: usize,
    /// Processes per node.
    pub nppn: usize,
    /// Threads per process (the paper fixes this per experiment).
    pub threads: usize,
    /// Slots charged per process (1 → 3 GB, 2 → 6 GB).
    pub slots_per_job: usize,
    /// User core allocation limit.
    pub allocation: usize,
}

impl TriplesConfig {
    /// The paper's Table I/II configuration family: 2 slots/job (6 GB) on
    /// the 4096-core allocation. `cores` is the table's "allocated compute
    /// cores" column = processes × slots_per_job.
    pub fn table_config(cores: usize, nppn: usize) -> Result<Self> {
        let slots_per_job = 2;
        if cores % slots_per_job != 0 {
            bail!("cores {cores} not divisible by slots_per_job");
        }
        let processes = cores / slots_per_job;
        if processes % nppn != 0 {
            bail!("processes {processes} not divisible by NPPN {nppn}");
        }
        let cfg = TriplesConfig {
            nodes: processes / nppn,
            nppn,
            threads: 1,
            slots_per_job,
            allocation: DEFAULT_ALLOCATION,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The §V follow-up configuration: 128 nodes, NPPN 8, 2 threads,
    /// single 3 GB slot, on the upgraded 8192-core allocation.
    pub fn followup_config() -> Self {
        TriplesConfig {
            nodes: 128,
            nppn: 8,
            threads: 2,
            slots_per_job: 1,
            allocation: UPGRADED_ALLOCATION,
        }
    }

    /// Total processes launched.
    pub fn processes(&self) -> usize {
        self.nodes * self.nppn
    }

    /// Self-scheduling worker count (one process is the manager).
    pub fn workers(&self) -> usize {
        self.processes().saturating_sub(1)
    }

    /// Cores charged against the allocation (exclusive mode).
    pub fn charged_cores(&self) -> usize {
        self.nodes * SLOTS_PER_NODE * self.slots_per_job
    }

    /// Memory available to each process, GB.
    pub fn gb_per_process(&self) -> f64 {
        GB_PER_SLOT * self.slots_per_job as f64
    }

    /// Validate against the LLSC rules. Returns a descriptive error for
    /// infeasible configurations (the "-" cells of Tables I-II).
    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.nppn == 0 || self.threads == 0 {
            bail!("nodes/nppn/threads must be positive");
        }
        if self.nodes > MAX_NODES && self.allocation <= DEFAULT_ALLOCATION {
            bail!("{} nodes exceeds the {MAX_NODES}-node job ceiling", self.nodes);
        }
        if self.nppn > 32 {
            bail!("NPPN {} exceeds the recommended max of 32", self.nppn);
        }
        if self.nppn % 8 != 0 {
            bail!("NPPN {} is not a multiple of 8 (xeon64c memory constraint)", self.nppn);
        }
        if self.nppn * self.threads > SLOTS_PER_NODE {
            bail!(
                "NPPN {} x threads {} oversubscribes the {SLOTS_PER_NODE}-core node",
                self.nppn,
                self.threads
            );
        }
        let charged = self.charged_cores();
        if charged > self.allocation {
            bail!(
                "exclusive mode charges {charged} cores ({} nodes x {SLOTS_PER_NODE} \
                 x {} slots) > allocation {}",
                self.nodes,
                self.slots_per_job,
                self.allocation
            );
        }
        if self.processes() < 2 {
            bail!("need at least 2 processes (manager + 1 worker)");
        }
        Ok(())
    }
}

/// A laptop-scale downscaling of a feasible triples-mode cell: how many
/// real processes (manager + workers) to launch locally for it. Produced
/// by [`TriplesConfig::plan_local`] and consumed by
/// [`crate::launch::LocalLauncher`]. The LLSC-specific rules (NPPN a
/// multiple of 8, 64-core nodes) deliberately do not apply to a laptop;
/// what the plan preserves is the cell's *shape* — its nodes : NPPN
/// proportion — so two table cells keep their relative process placement
/// when both are scaled down to the same machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LocalPlan {
    /// Simulated node groups (ratio bookkeeping only — everything runs on
    /// one physical machine).
    pub nodes: usize,
    /// Worker processes per simulated node.
    pub nppn: usize,
    /// Threads per worker process (carried through from the cell).
    pub threads: usize,
}

impl LocalPlan {
    /// Total local processes (manager + workers).
    pub fn processes(&self) -> usize {
        self.nodes * self.nppn
    }

    /// Worker subprocesses to spawn (one process is the manager).
    pub fn workers(&self) -> usize {
        self.processes().saturating_sub(1)
    }
}

/// Greatest common divisor (Euclid).
fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl TriplesConfig {
    /// Downscale this cell to a feasible *local* process count: at most
    /// `max_procs` processes (manager included), at least 2 (manager +
    /// one worker), preserving the cell's nodes : NPPN ratio exactly
    /// whenever that ratio fits. Infeasible cells (the "-" entries of
    /// Tables I-II) are rejected up front with their violated rule, so a
    /// local run can never silently "fix" a configuration the LLSC would
    /// refuse.
    pub fn plan_local(&self, max_procs: usize) -> Result<LocalPlan> {
        self.validate()?;
        if max_procs < 2 {
            bail!("max_procs {max_procs} cannot host a manager and a worker");
        }
        let procs = self.processes();
        if procs <= max_procs {
            // Already laptop-sized; run it as-is.
            return Ok(LocalPlan { nodes: self.nodes, nppn: self.nppn, threads: self.threads });
        }
        // Smallest integer pair with the exact nodes : NPPN ratio, scaled
        // back up by the largest k that still fits under the cap (and
        // never beyond the original cell).
        let g = gcd(self.nodes, self.nppn);
        let (b_nodes, b_nppn) = (self.nodes / g, self.nppn / g);
        let base = b_nodes * b_nppn;
        if base > max_procs {
            // The exact ratio cannot fit; fall back to the densest local
            // shape (one node group, capped NPPN).
            return Ok(LocalPlan { nodes: 1, nppn: max_procs, threads: self.threads });
        }
        let mut k = 1usize;
        while k < g && (k + 1) * (k + 1) * base <= max_procs {
            k += 1;
        }
        let mut plan = LocalPlan { nodes: b_nodes * k, nppn: b_nppn * k, threads: self.threads };
        if plan.processes() < 2 {
            // A 1x1 ratio at k=1: bump to the minimum viable pair.
            plan.nppn = 2;
        }
        Ok(plan)
    }
}

/// The Table I/II sweep: NPPN rows x core columns, in paper order. Returns
/// `(cores, nppn, Result<TriplesConfig>)` for all 12 cells — infeasible
/// cells carry the validation error (rendered as "-").
pub fn table_sweep() -> Vec<(usize, usize, Result<TriplesConfig>)> {
    let mut out = Vec::new();
    for &nppn in &[32usize, 16, 8] {
        for &cores in &[2048usize, 1024, 512, 256] {
            out.push((cores, nppn, TriplesConfig::table_config(cores, nppn)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_feasibility_pattern() {
        // Populated cells of Tables I-II validate; "-" cells do not.
        let feasible = [
            (2048, 32),
            (1024, 32),
            (512, 32),
            (256, 32),
            (1024, 16),
            (512, 16),
            (256, 16),
            (512, 8),
            (256, 8),
        ];
        let infeasible = [(2048, 16), (2048, 8), (1024, 8)];
        for (cores, nppn) in feasible {
            assert!(
                TriplesConfig::table_config(cores, nppn).is_ok(),
                "({cores},{nppn}) should be feasible"
            );
        }
        for (cores, nppn) in infeasible {
            assert!(
                TriplesConfig::table_config(cores, nppn).is_err(),
                "({cores},{nppn}) should be infeasible"
            );
        }
    }

    #[test]
    fn worker_counts_match_paper() {
        // Fig 5-6: "one manager and 255 workers" at the 512-core column.
        let cfg = TriplesConfig::table_config(512, 32).unwrap();
        assert_eq!(cfg.processes(), 256);
        assert_eq!(cfg.workers(), 255);
        // Table I headline cell: 2048 cores, NPPN 32 -> 1024 processes.
        let big = TriplesConfig::table_config(2048, 32).unwrap();
        assert_eq!(big.processes(), 1024);
        assert_eq!(big.nodes, 32);
    }

    #[test]
    fn memory_accounting() {
        let cfg = TriplesConfig::table_config(512, 16).unwrap();
        assert_eq!(cfg.gb_per_process(), 6.0);
        assert_eq!(cfg.charged_cores(), 16 * 64 * 2);
        let f = TriplesConfig::followup_config();
        assert_eq!(f.gb_per_process(), 3.0);
        assert!(f.validate().is_ok());
        assert_eq!(f.processes(), 1024);
    }

    #[test]
    fn rule_violations_are_caught() {
        let base = TriplesConfig {
            nodes: 4,
            nppn: 16,
            threads: 1,
            slots_per_job: 2,
            allocation: DEFAULT_ALLOCATION,
        };
        assert!(base.validate().is_ok());
        assert!(TriplesConfig { nppn: 40, ..base }.validate().is_err()); // > 32
        assert!(TriplesConfig { nppn: 12, ..base }.validate().is_err()); // not x8
        assert!(TriplesConfig { threads: 9, nppn: 8, ..base }.validate().is_err()); // 72 > 64
        assert!(TriplesConfig { nodes: 100, ..base }.validate().is_err()); // > 64 nodes
        assert!(TriplesConfig { nodes: 0, ..base }.validate().is_err());
    }

    #[test]
    fn sweep_has_12_cells_9_feasible() {
        let sweep = table_sweep();
        assert_eq!(sweep.len(), 12);
        assert_eq!(sweep.iter().filter(|(_, _, r)| r.is_ok()).count(), 9);
    }

    /// Every "-" cell of Tables I-II must reject with the *specific*
    /// violated rule, not a generic failure — the launch layer surfaces
    /// these messages to users planning local runs.
    #[test]
    fn each_infeasible_cell_names_its_violated_rule() {
        // (2048, 16): 1024 processes over 64 nodes -> 64x64x2 = 8192
        // charged cores > the 4096 allocation.
        let e = TriplesConfig::table_config(2048, 16).unwrap_err();
        assert!(format!("{e:#}").contains("allocation"), "{e:#}");
        // (2048, 8): 1024 processes over 128 nodes > the 64-node ceiling.
        let e = TriplesConfig::table_config(2048, 8).unwrap_err();
        assert!(format!("{e:#}").contains("node ceiling"), "{e:#}");
        // (1024, 8): 512 processes over 64 nodes -> 8192 charged > 4096.
        let e = TriplesConfig::table_config(1024, 8).unwrap_err();
        assert!(format!("{e:#}").contains("allocation"), "{e:#}");

        // The four rule families, probed directly.
        let base = TriplesConfig {
            nodes: 4,
            nppn: 16,
            threads: 1,
            slots_per_job: 2,
            allocation: DEFAULT_ALLOCATION,
        };
        let e = TriplesConfig { nppn: 40, ..base }.validate().unwrap_err();
        assert!(format!("{e:#}").contains("max of 32"), "{e:#}");
        let e = TriplesConfig { nppn: 12, ..base }.validate().unwrap_err();
        assert!(format!("{e:#}").contains("multiple of 8"), "{e:#}");
        let e = TriplesConfig { nodes: 65, ..base }.validate().unwrap_err();
        assert!(format!("{e:#}").contains("node ceiling"), "{e:#}");
        let e = TriplesConfig { nodes: 33, ..base }.validate().unwrap_err();
        assert!(format!("{e:#}").contains("allocation"), "{e:#}");
    }

    #[test]
    fn plan_local_preserves_ratio_and_feasibility() {
        // Every feasible table cell downscales to a runnable local plan
        // (2..=max processes) with the exact nodes : NPPN ratio.
        for (cores, nppn, cfg) in table_sweep() {
            let Ok(cfg) = cfg else { continue };
            let plan = cfg.plan_local(8).unwrap();
            assert!(
                plan.processes() >= 2 && plan.processes() <= 8,
                "({cores},{nppn}) planned {} processes",
                plan.processes()
            );
            assert!(plan.workers() >= 1, "({cores},{nppn}) has no workers");
            assert_eq!(
                plan.nppn * cfg.nodes,
                cfg.nppn * plan.nodes,
                "({cores},{nppn}) broke the nodes:NPPN ratio: {plan:?}"
            );
            assert_eq!(plan.threads, cfg.threads);
        }
        // Infeasible cells are rejected by the local planner too — the
        // laptop must not silently "fix" an LLSC-invalid configuration.
        for (cores, nppn) in [(2048, 16), (2048, 8), (1024, 8)] {
            let cfg = TriplesConfig {
                nodes: cores / 2 / nppn,
                nppn,
                threads: 1,
                slots_per_job: 2,
                allocation: DEFAULT_ALLOCATION,
            };
            assert!(cfg.plan_local(8).is_err(), "({cores},{nppn}) must not plan");
        }
    }

    #[test]
    fn plan_local_edge_cases() {
        let cell = TriplesConfig::table_config(512, 32).unwrap(); // 256 procs
        // A cap below manager+worker is rejected.
        assert!(cell.plan_local(1).is_err());
        // A cap the exact ratio cannot fit falls back to one dense group.
        let tight = cell.plan_local(2).unwrap(); // base ratio 1:4 needs 4
        assert_eq!((tight.nodes, tight.nppn), (1, 2));
        // An already-laptop-sized config passes through unchanged.
        let small = TriplesConfig {
            nodes: 1,
            nppn: 8,
            threads: 1,
            slots_per_job: 1,
            allocation: DEFAULT_ALLOCATION,
        };
        let plan = small.plan_local(16).unwrap();
        assert_eq!((plan.nodes, plan.nppn), (1, 8));
        // The 1x1-ratio headline cell still yields a worker at tiny caps.
        let big = TriplesConfig::table_config(2048, 32).unwrap();
        let plan = big.plan_local(3).unwrap();
        assert_eq!(plan.processes(), 2);
        assert_eq!(plan.workers(), 1);
    }
}
