"""L2: the stage-3 track-processing compute graph (build-time JAX).

Composes the L1 Pallas kernels into the batched computation the rust
coordinator executes on the request path: resample padded track segments
onto a uniform grid, estimate dynamic rates, and compute AGL altitude over
the batch's shared DEM tile. Lowered once by ``aot.py`` to HLO text; Python
never runs at request time.

Default AOT shapes (see ``aot.py --help`` to override):
  B  = 16   tracks per batch
  N  = 128  padded observations per track
  M  = 64   output grid points per track
  TH = TW = 64  DEM tile
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.agl import agl_tracks
from compile.kernels.interp import interp_tracks
from compile.kernels import ref

# Input order is the ABI contract with rust/src/runtime (see the artifact
# manifest written by aot.py).
INPUT_NAMES = (
    "obs_t", "obs_lat", "obs_lon", "obs_alt", "obs_valid",
    "grid_t", "dem", "dem_meta",
)
OUTPUT_NAMES = ("lat", "lon", "alt", "vrate", "gspeed", "agl", "valid")

DEFAULT_B = 16
DEFAULT_N = 128
DEFAULT_M = 64
DEFAULT_TILE = 64


def track_model(obs_t, obs_lat, obs_lon, obs_alt, obs_valid, grid_t, dem, dem_meta):
    """Full stage-3 batch computation (Pallas path).

    Returns a 7-tuple of ``[B, M]`` f32 arrays in ``OUTPUT_NAMES`` order.
    Rows with fewer than two valid observations yield zeros with
    ``valid = 0``.
    """
    lat, lon, alt, vrate, gspeed, valid = interp_tracks(
        obs_t, obs_lat, obs_lon, obs_alt, obs_valid, grid_t
    )
    agl, _elev = agl_tracks(lat, lon, alt, dem, dem_meta)
    return lat, lon, alt, vrate, gspeed, agl * valid, valid


def track_model_ref(*args):
    """Pure-jnp oracle with the identical signature (testing only)."""
    return ref.track_model_ref(*args)


def example_args(b=DEFAULT_B, n=DEFAULT_N, m=DEFAULT_M, tile=DEFAULT_TILE):
    """ShapeDtypeStructs for AOT lowering, in ``INPUT_NAMES`` order."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((b, n), f32),   # obs_t
        jax.ShapeDtypeStruct((b, n), f32),   # obs_lat
        jax.ShapeDtypeStruct((b, n), f32),   # obs_lon
        jax.ShapeDtypeStruct((b, n), f32),   # obs_alt
        jax.ShapeDtypeStruct((b, n), f32),   # obs_valid
        jax.ShapeDtypeStruct((b, m), f32),   # grid_t
        jax.ShapeDtypeStruct((tile, tile), f32),  # dem
        jax.ShapeDtypeStruct((4,), f32),     # dem_meta
    )
