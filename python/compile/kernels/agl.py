"""L1 Pallas kernel: DEM bilinear lookup -> above-ground-level altitude.

Stage 3 of the paper's workflow calculates AGL altitude for every
interpolated track point by subtracting terrain elevation (NOAA GLOBE DEM,
§III.B) from the MSL altitude. The DEM tile for the track's region is staged
into VMEM once per track; §V attributes the radar dataset's better task
economics to exactly this footprint ("the amount of DEM data required was
constrained by the surveillance range of the radar").

TPU adaptation: bilinear interpolation is a 2-D gather in its natural form.
Here each query point's row/col fractional weights become sparse weight
vectors, and the whole lookup becomes two dense matmuls:

    elev[m] = r_m^T · D · c_m    =>    elev = rowsum((R @ D) * C)

with ``R: [M, TH]`` (two nonzeros per row: 1-fy at y0, fy at y0+1) and
``C: [M, TW]`` likewise for columns. ``R @ D`` is an MXU matmul; the final
blend is a VPU reduction. No data-dependent addressing anywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Metres -> feet (DEM elevations are metres; altitudes are feet MSL).
FT_PER_M = 3.28084


def _weights(coord, origin, step, size):
    """Fractional index + clamped one-hot-pair weight matrix ``[M, size]``.

    ``coord`` is the query coordinate vector ``[M]``; the DEM axis starts at
    ``origin`` with spacing ``step`` and ``size`` samples. Queries outside
    the tile clamp to the border (matching the reference oracle).
    """
    idx = (coord - origin) / step
    idx = jnp.clip(idx, 0.0, size - 1.000001)
    i0 = jnp.floor(idx)
    frac = idx - i0
    iota = jax.lax.broadcasted_iota(jnp.float32, (coord.shape[0], size), 1)
    w0 = (iota == i0[:, None]).astype(jnp.float32) * (1.0 - frac)[:, None]
    w1 = (iota == (i0 + 1.0)[:, None]).astype(jnp.float32) * frac[:, None]
    return w0 + w1


def _agl_body(lat_ref, lon_ref, alt_ref, dem_ref, meta_ref, agl_ref, elev_ref):
    """One track per grid step; DEM tile is broadcast to every step."""
    lat = lat_ref[0, :]
    lon = lon_ref[0, :]
    alt = alt_ref[0, :]
    dem = dem_ref[...]
    # meta = [lat0, lon0, dlat, dlon]
    lat0 = meta_ref[0]
    lon0 = meta_ref[1]
    dlat = meta_ref[2]
    dlon = meta_ref[3]

    th, tw = dem.shape
    r = _weights(lat, lat0, dlat, th)   # [M, TH]
    c = _weights(lon, lon0, dlon, tw)   # [M, TW]

    rd = jnp.dot(r, dem, preferred_element_type=jnp.float32)  # [M, TW]
    elev_m = jnp.sum(rd * c, axis=1)                          # metres
    elev_ft = elev_m * FT_PER_M

    agl_ref[0, :] = alt - elev_ft
    elev_ref[0, :] = elev_ft


def agl_tracks(lat, lon, alt, dem, dem_meta):
    """AGL altitude for a batch of interpolated tracks over one DEM tile.

    Args:
      lat: ``[B, M]`` f32 latitude (deg).
      lon: ``[B, M]`` f32 longitude (deg).
      alt: ``[B, M]`` f32 MSL altitude (ft).
      dem: ``[TH, TW]`` f32 terrain elevation tile (metres MSL). All tracks
        in the batch share one tile — the rust coordinator groups track
        batches by region, mirroring the per-radar DEM footprint of §V.
      dem_meta: ``[4]`` f32 ``(lat0, lon0, dlat, dlon)`` — tile origin and
        per-cell spacing in degrees.

    Returns:
      ``(agl, elev)`` — each ``[B, M]`` f32, feet. ``agl = alt - elev``.
    """
    b, m = lat.shape
    grid_spec = pl.BlockSpec((1, m), lambda i: (i, 0))
    dem_spec = pl.BlockSpec(dem.shape, lambda i: (0, 0))
    meta_spec = pl.BlockSpec((4,), lambda i: (0,))
    return pl.pallas_call(
        _agl_body,
        grid=(b,),
        in_specs=[grid_spec, grid_spec, grid_spec, dem_spec, meta_spec],
        out_specs=[grid_spec, grid_spec],
        out_shape=[jax.ShapeDtypeStruct((b, m), jnp.float32)] * 2,
        interpret=True,
    )(lat, lon, alt, dem, dem_meta)
