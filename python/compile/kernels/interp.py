"""L1 Pallas kernel: masked track interpolation + dynamic-rate estimation.

The stage-3 hot spot of the paper's workflow ("processing and interpolating
into track segments", §III.A): each aircraft track segment — an irregular,
padded sequence of surveillance observations — is resampled onto a uniform
time grid, and dynamic rates (vertical rate, ground speed) are estimated
with central differences on the resampled signal.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the natural formulation is
a per-output-point ``searchsorted`` + gather, which maps poorly onto the
MXU/VPU. Instead the bracketing indices are turned into one-hot matrices and
the value lookups become ``[M, N] @ [N, F]`` matmuls — MXU-shaped work with
no data-dependent addressing. The per-track working set (N-point track block
+ M-point grid + the two one-hot matrices) is ~0.2 MB, far inside VMEM; the
batch dimension is the Pallas grid.

All kernels run with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, so interpret mode is the correctness (and AOT) path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# A time value larger than any real track timestamp; padded (invalid)
# observations are moved to +BIG_T so they never bracket a grid point.
BIG_T = 1.0e9
# Guard for zero-length bracketing intervals (duplicate timestamps).
EPS_T = 1.0e-6
# Feet per degree of latitude (60 nm * 6076.12 ft) — used by ground speed.
NM_PER_DEG = 60.0


def _interp_body(
    t_ref, lat_ref, lon_ref, alt_ref, valid_ref, grid_ref,
    olat_ref, olon_ref, oalt_ref, ovr_ref, ogs_ref, ovalid_ref,
):
    """Kernel body for one track (one Pallas grid step).

    Refs hold ``[1, N]`` (track) and ``[1, M]`` (grid) blocks staged into
    VMEM by the BlockSpecs in :func:`interp_tracks`.
    """
    t = t_ref[0, :]
    valid = valid_ref[0, :]
    grid = grid_ref[0, :]
    n = t.shape[0]
    m = grid.shape[0]

    # Padded entries must never bracket a grid point.
    t_eff = jnp.where(valid > 0.5, t, BIG_T)
    n_valid = jnp.sum(valid)

    # cnt[m] = number of valid observations with time <= grid[m].
    # [M, N] comparison matrix; row-sum gives the counts. This is the
    # "searchsorted" of the classic formulation, done as a dense masked
    # reduction (VPU-shaped, no data-dependent control flow).
    le = (t_eff[None, :] <= grid[:, None]).astype(jnp.float32)
    cnt = jnp.sum(le, axis=1)

    # Bracketing indices, clamped to the valid range so out-of-span grid
    # points clamp to the track endpoints (constant extrapolation).
    last = jnp.maximum(n_valid - 1.0, 0.0)
    idx_lo = jnp.clip(cnt - 1.0, 0.0, last)
    idx_hi = jnp.clip(cnt, 0.0, last)

    # One-hot [M, N] selection matrices; the value lookups below become
    # matmuls instead of gathers (MXU-friendly on real TPU).
    iota = jax.lax.broadcasted_iota(jnp.float32, (m, n), 1)
    oh_lo = (iota == idx_lo[:, None]).astype(jnp.float32)
    oh_hi = (iota == idx_hi[:, None]).astype(jnp.float32)

    # Stack features [N, F]: time, lat, lon, alt. Two [M,N]@[N,F] matmuls
    # fetch both bracket endpoints for every feature at once.
    feats = jnp.stack([t, lat_ref[0, :], lon_ref[0, :], alt_ref[0, :]], axis=1)
    f_lo = jnp.dot(oh_lo, feats, preferred_element_type=jnp.float32)
    f_hi = jnp.dot(oh_hi, feats, preferred_element_type=jnp.float32)

    t_lo, lat_lo, lon_lo, alt_lo = (f_lo[:, i] for i in range(4))
    t_hi, lat_hi, lon_hi, alt_hi = (f_hi[:, i] for i in range(4))

    dt_b = t_hi - t_lo
    frac = jnp.clip((grid - t_lo) / jnp.where(dt_b > EPS_T, dt_b, 1.0), 0.0, 1.0)
    frac = jnp.where(dt_b > EPS_T, frac, 0.0)

    o_lat = lat_lo + frac * (lat_hi - lat_lo)
    o_lon = lon_lo + frac * (lon_hi - lon_lo)
    o_alt = alt_lo + frac * (alt_hi - alt_lo)

    # Uniform grid spacing (grid is generated uniform by the coordinator).
    gdt = jnp.maximum(grid[1] - grid[0], EPS_T)

    # Central differences via static shifts (M is static): pad-edge scheme
    # gives one-sided differences at the ends with the same denominators as
    # the reference oracle.
    def cdiff(x):
        x_next = jnp.concatenate([x[1:], x[-1:]])
        x_prev = jnp.concatenate([x[:1], x[:-1]])
        # interior: (x[i+1]-x[i-1])/(2dt); edges: one-sided /dt.
        span = jnp.concatenate(
            [jnp.ones((1,)), 2.0 * jnp.ones((m - 2,)), jnp.ones((1,))]
        )
        return (x_next - x_prev) / (span * gdt)

    # Vertical rate: ft/s -> ft/min.
    vrate = cdiff(o_alt) * 60.0
    # Ground speed: degrees -> nm (lon scaled by cos(lat)), nm/s -> knots.
    dlat = cdiff(o_lat) * NM_PER_DEG
    coslat = jnp.cos(jnp.deg2rad(o_lat))
    dlon = cdiff(o_lon) * NM_PER_DEG * coslat
    gspeed = jnp.sqrt(dlat * dlat + dlon * dlon) * 3600.0

    ovalid = jnp.broadcast_to((n_valid >= 2.0).astype(jnp.float32), (m,))

    olat_ref[0, :] = o_lat * ovalid
    olon_ref[0, :] = o_lon * ovalid
    oalt_ref[0, :] = o_alt * ovalid
    ovr_ref[0, :] = vrate * ovalid
    ogs_ref[0, :] = gspeed * ovalid
    ovalid_ref[0, :] = ovalid


@functools.partial(jax.jit, static_argnames=())
def interp_tracks(obs_t, obs_lat, obs_lon, obs_alt, obs_valid, grid_t):
    """Resample a batch of padded track segments onto uniform time grids.

    Args:
      obs_t:     ``[B, N]`` f32 observation times (s), valid entries ascending.
      obs_lat:   ``[B, N]`` f32 latitude (deg).
      obs_lon:   ``[B, N]`` f32 longitude (deg).
      obs_alt:   ``[B, N]`` f32 MSL altitude (ft).
      obs_valid: ``[B, N]`` f32 {0,1} validity mask.
      grid_t:    ``[B, M]`` f32 uniform output time grid (s).

    Returns:
      ``(lat, lon, alt, vrate, gspeed, valid)`` — each ``[B, M]`` f32;
      ``vrate`` in ft/min, ``gspeed`` in knots, ``valid`` {0,1} (1 iff the
      row had >= 2 valid observations). Rows with < 2 valid observations
      produce all-zero outputs.
    """
    b, n = obs_t.shape
    m = grid_t.shape[1]
    track_spec = pl.BlockSpec((1, n), lambda i: (i, 0))
    grid_spec = pl.BlockSpec((1, m), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((b, m), jnp.float32)] * 6
    return tuple(
        pl.pallas_call(
            _interp_body,
            grid=(b,),
            in_specs=[track_spec] * 5 + [grid_spec],
            out_specs=[grid_spec] * 6,
            out_shape=out_shape,
            interpret=True,
        )(obs_t, obs_lat, obs_lon, obs_alt, obs_valid, grid_t)
    )
