"""Pure-jnp reference oracle for the L1 Pallas kernels.

Implements the *mathematical spec* of track resampling + AGL with the
natural searchsorted/gather formulation (no one-hot matmuls, no Pallas).
pytest compares the Pallas kernels against these functions; the checked-in
golden values used by the rust integration tests are generated from here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BIG_T = 1.0e9
EPS_T = 1.0e-6
NM_PER_DEG = 60.0
FT_PER_M = 3.28084


def _interp_one(t, lat, lon, alt, valid, grid):
    """Reference resampling for a single track. All args 1-D."""
    n = t.shape[0]
    m = grid.shape[0]
    t_eff = jnp.where(valid > 0.5, t, BIG_T)
    n_valid = jnp.sum(valid)

    cnt = jnp.sum((t_eff[None, :] <= grid[:, None]).astype(jnp.float32), axis=1)
    last = jnp.maximum(n_valid - 1.0, 0.0)
    idx_lo = jnp.clip(cnt - 1.0, 0.0, last).astype(jnp.int32)
    idx_hi = jnp.clip(cnt, 0.0, last).astype(jnp.int32)

    def take(x, i):
        return jnp.take(x, i, axis=0)

    t_lo, t_hi = take(t, idx_lo), take(t, idx_hi)
    dt = t_hi - t_lo
    frac = jnp.clip((grid - t_lo) / jnp.where(dt > EPS_T, dt, 1.0), 0.0, 1.0)
    frac = jnp.where(dt > EPS_T, frac, 0.0)

    def lerp(x):
        lo, hi = take(x, idx_lo), take(x, idx_hi)
        return lo + frac * (hi - lo)

    o_lat, o_lon, o_alt = lerp(lat), lerp(lon), lerp(alt)

    gdt = jnp.maximum(grid[1] - grid[0], EPS_T)

    def cdiff(x):
        x_next = jnp.concatenate([x[1:], x[-1:]])
        x_prev = jnp.concatenate([x[:1], x[:-1]])
        span = jnp.concatenate(
            [jnp.ones((1,)), 2.0 * jnp.ones((m - 2,)), jnp.ones((1,))]
        )
        return (x_next - x_prev) / (span * gdt)

    vrate = cdiff(o_alt) * 60.0
    dlat = cdiff(o_lat) * NM_PER_DEG
    dlon = cdiff(o_lon) * NM_PER_DEG * jnp.cos(jnp.deg2rad(o_lat))
    gspeed = jnp.sqrt(dlat * dlat + dlon * dlon) * 3600.0

    ovalid = jnp.broadcast_to((n_valid >= 2.0).astype(jnp.float32), (m,))
    return (
        o_lat * ovalid,
        o_lon * ovalid,
        o_alt * ovalid,
        vrate * ovalid,
        gspeed * ovalid,
        ovalid,
    )


def interp_tracks_ref(obs_t, obs_lat, obs_lon, obs_alt, obs_valid, grid_t):
    """Batched reference resampling; same signature/returns as the kernel."""
    return jax.vmap(_interp_one)(obs_t, obs_lat, obs_lon, obs_alt, obs_valid, grid_t)


def _bilinear_one(lat, lon, dem, meta):
    """Reference border-clamped bilinear DEM sample for one track (metres)."""
    th, tw = dem.shape
    ri = jnp.clip((lat - meta[0]) / meta[2], 0.0, th - 1.000001)
    ci = jnp.clip((lon - meta[1]) / meta[3], 0.0, tw - 1.000001)
    r0 = jnp.floor(ri).astype(jnp.int32)
    c0 = jnp.floor(ci).astype(jnp.int32)
    fr = ri - r0
    fc = ci - c0
    d00 = dem[r0, c0]
    d01 = dem[r0, c0 + 1]
    d10 = dem[r0 + 1, c0]
    d11 = dem[r0 + 1, c0 + 1]
    top = d00 * (1 - fc) + d01 * fc
    bot = d10 * (1 - fc) + d11 * fc
    return top * (1 - fr) + bot * fr


def agl_tracks_ref(lat, lon, alt, dem, dem_meta):
    """Batched reference AGL; same signature/returns as the kernel."""
    elev_m = jax.vmap(lambda la, lo: _bilinear_one(la, lo, dem, dem_meta))(lat, lon)
    elev_ft = elev_m * FT_PER_M
    return alt - elev_ft, elev_ft


def track_model_ref(obs_t, obs_lat, obs_lon, obs_alt, obs_valid, grid_t, dem, dem_meta):
    """Reference for the full L2 model (interp + rates + AGL)."""
    lat, lon, alt, vrate, gspeed, valid = interp_tracks_ref(
        obs_t, obs_lat, obs_lon, obs_alt, obs_valid, grid_t
    )
    agl, elev = agl_tracks_ref(lat, lon, alt, dem, dem_meta)
    return lat, lon, alt, vrate, gspeed, agl * valid, valid
