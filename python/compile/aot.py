"""AOT: lower the L2 track model to HLO text for the rust PJRT runtime.

HLO *text* (not a serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. The stablehlo module is converted to
an ``XlaComputation`` with ``return_tuple=True``; the rust side unwraps the
tuple.

Besides the HLO, a plain-text manifest (``key=value`` lines — serde is not
available to the offline rust build) records the shapes and the input/output
ABI so the runtime can size its buffers without parsing HLO.

Usage:
  python -m compile.aot --out ../artifacts/track_model.hlo.txt [--b 16]
      [--n 128] [--m 64] [--tile 64] [--check]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as model_mod


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides array constants as ``{...}``, which the rust-side HLO text parser
    silently reads back as zeros (observed: the central-difference span
    constant became 0 => every rate output was inf).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def manifest_text(b: int, n: int, m: int, tile: int) -> str:
    lines = [
        "name=track_model",
        f"b={b}",
        f"n={n}",
        f"m={m}",
        f"tile={tile}",
        "inputs=" + ",".join(model_mod.INPUT_NAMES),
        "outputs=" + ",".join(model_mod.OUTPUT_NAMES),
        "dtype=f32",
        "return_tuple=1",
    ]
    return "\n".join(lines) + "\n"


def golden_inputs(b: int, n: int, m: int, tile: int):
    """Deterministic inputs for the cross-language golden file."""
    import numpy as np

    rng = np.random.default_rng(4242)
    t = np.sort(rng.uniform(0, 600, (b, n)).astype(np.float32), axis=1)
    lat = (42.0 + np.cumsum(rng.normal(0, 1e-3, (b, n)), axis=1)).astype(np.float32)
    lon = (-71.0 + np.cumsum(rng.normal(0, 1e-3, (b, n)), axis=1)).astype(np.float32)
    alt = rng.uniform(50, 5000, (b, n)).astype(np.float32)
    valid = (rng.uniform(size=(b, n)) < 0.9).astype(np.float32)
    grid = np.linspace(0, 600, m, dtype=np.float32)[None, :].repeat(b, axis=0)
    dem = rng.uniform(0, 500, (tile, tile)).astype(np.float32)
    meta = np.array([41.5, -71.5, 0.02, 0.02], dtype=np.float32)
    return (t, lat, lon, alt, valid, grid, dem, meta)


def write_golden(path: str, b: int, n: int, m: int, tile: int) -> None:
    """Golden i/o pairs (oracle numerics) for rust/tests/runtime_golden.rs."""
    import numpy as np

    args = golden_inputs(b, n, m, tile)
    out = model_mod.track_model_ref(*map(jnp.asarray, args))
    with open(path, "w") as f:
        f.write(f"# golden i/o for track_model b={b} n={n} m={m} tile={tile}\n")
        for name, arr in zip(model_mod.INPUT_NAMES, args):
            flat = np.asarray(arr, dtype=np.float32).ravel()
            f.write(f"in {name} {' '.join(repr(float(v)) for v in flat)}\n")
        for name, arr in zip(model_mod.OUTPUT_NAMES, out):
            flat = np.asarray(arr, dtype=np.float32).ravel()
            f.write(f"out {name} {' '.join(repr(float(v)) for v in flat)}\n")


def run_check(b: int, n: int, m: int, tile: int) -> float:
    """Execute the pallas path vs the oracle on random inputs; max |err|."""
    import numpy as np

    rng = np.random.default_rng(0)
    t = np.sort(rng.uniform(0, 600, (b, n)).astype(np.float32), axis=1)
    lat = (42.0 + np.cumsum(rng.normal(0, 1e-3, (b, n)), axis=1)).astype(np.float32)
    lon = (-71.0 + np.cumsum(rng.normal(0, 1e-3, (b, n)), axis=1)).astype(np.float32)
    alt = rng.uniform(50, 5000, (b, n)).astype(np.float32)
    valid = (rng.uniform(size=(b, n)) < 0.9).astype(np.float32)
    grid = np.linspace(0, 600, m, dtype=np.float32)[None, :].repeat(b, axis=0)
    dem = rng.uniform(0, 500, (tile, tile)).astype(np.float32)
    meta = np.array([41.5, -71.5, 0.02, 0.02], dtype=np.float32)

    args = (t, lat, lon, alt, valid, grid, dem, meta)
    got = model_mod.track_model(*map(jnp.asarray, args))
    want = model_mod.track_model_ref(*map(jnp.asarray, args))
    # Scale-aware: normalize by each output's magnitude (altitudes are in the
    # thousands of feet; raw f32 abs error there is ~1e-3).
    return max(
        float(jnp.max(jnp.abs(g - w)) / (1.0 + jnp.max(jnp.abs(w))))
        for g, w in zip(got, want)
    )


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts/track_model.hlo.txt")
    p.add_argument("--b", type=int, default=model_mod.DEFAULT_B)
    p.add_argument("--n", type=int, default=model_mod.DEFAULT_N)
    p.add_argument("--m", type=int, default=model_mod.DEFAULT_M)
    p.add_argument("--tile", type=int, default=model_mod.DEFAULT_TILE)
    p.add_argument("--check", action="store_true",
                   help="also execute pallas vs oracle and report max error")
    a = p.parse_args()

    spec = model_mod.example_args(a.b, a.n, a.m, a.tile)
    lowered = jax.jit(model_mod.track_model).lower(*spec)
    text = to_hlo_text(lowered)

    out = os.path.abspath(a.out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write(text)
    manifest = out.rsplit(".hlo.txt", 1)[0] + ".manifest"
    with open(manifest, "w") as f:
        f.write(manifest_text(a.b, a.n, a.m, a.tile))
    golden = os.path.join(os.path.dirname(out), "golden_track_model.txt")
    write_golden(golden, a.b, a.n, a.m, a.tile)
    print(f"wrote {len(text)} chars to {out}")
    print(f"wrote manifest to {manifest}")
    print(f"wrote golden to {golden}")

    if a.check:
        err = run_check(a.b, a.n, a.m, a.tile)
        print(f"pallas-vs-oracle max scaled err: {err:.3e}")
        if err > 1e-4:
            sys.exit("AOT check FAILED")


if __name__ == "__main__":
    main()
