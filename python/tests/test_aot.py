"""AOT path: lowering to HLO text, manifest contents, golden generation.

Also writes the golden-values file consumed by the rust integration tests
(`rust/tests/runtime_golden.rs`) so both languages agree on the numerics of
the same artifact.
"""

import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model as model_mod

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def small_spec():
    return model_mod.example_args(2, 8, 4, 4)


def test_lowering_produces_hlo_text():
    lowered = jax.jit(model_mod.track_model).lower(*small_spec())
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text
    # 8 parameters (the ABI), tuple return.
    for i in range(8):
        assert f"parameter({i})" in text


def test_no_elided_constants_in_hlo_text():
    """Regression: the default printer elides array constants as `{...}`,
    which the rust text parser reads back as ZEROS (this made every rate
    output inf). print_large_constants=True must stay on."""
    lowered = jax.jit(model_mod.track_model).lower(*model_mod.example_args())
    text = aot.to_hlo_text(lowered)
    assert "{...}" not in text


def test_manifest_round_trip_fields():
    text = aot.manifest_text(16, 128, 64, 64)
    kv = dict(line.split("=", 1) for line in text.strip().splitlines())
    assert kv["name"] == "track_model"
    assert (kv["b"], kv["n"], kv["m"], kv["tile"]) == ("16", "128", "64", "64")
    assert kv["inputs"].split(",") == list(model_mod.INPUT_NAMES)
    assert kv["outputs"].split(",") == list(model_mod.OUTPUT_NAMES)


def test_aot_check_small():
    assert aot.run_check(4, 16, 8, 8) < 1e-3


def test_write_golden_for_rust():
    """Deterministic input/output pairs for the rust runtime integration
    test. Uses the AOT default shapes — the same artifact rust loads."""
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "golden_track_model.txt")
    aot.write_golden(path, model_mod.DEFAULT_B, model_mod.DEFAULT_N,
                     model_mod.DEFAULT_M, model_mod.DEFAULT_TILE)
    assert os.path.getsize(path) > 0
    with open(path) as f:
        lines = [l for l in f if not l.startswith("#")]
    ins = [l for l in lines if l.startswith("in ")]
    outs = [l for l in lines if l.startswith("out ")]
    assert len(ins) == len(model_mod.INPUT_NAMES)
    assert len(outs) == len(model_mod.OUTPUT_NAMES)

def test_golden_pallas_agrees_with_oracle_golden():
    """The artifact rust executes is the *pallas* lowering; verify its
    numerics agree with the oracle that wrote the golden file."""
    args = aot.golden_inputs(4, 16, 8, 8)
    got = model_mod.track_model(*map(jnp.asarray, args))
    want = model_mod.track_model_ref(*map(jnp.asarray, args))
    for name, g, w in zip(model_mod.OUTPUT_NAMES, got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-3, err_msg=name)
