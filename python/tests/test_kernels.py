"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and data regimes; every comparison is
assert_allclose against ref.py. These tests are the core correctness signal
for the numbers the rust runtime serves.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels.interp import interp_tracks
from compile.kernels.agl import agl_tracks
from compile.kernels import ref

RTOL = 1e-4
ATOL = 1e-3


def make_track_batch(rng, b, n, m, valid_frac=0.9, t_span=600.0):
    t = np.sort(rng.uniform(0, t_span, (b, n)).astype(np.float32), axis=1)
    lat = (40.0 + np.cumsum(rng.normal(0, 2e-3, (b, n)), axis=1)).astype(np.float32)
    lon = (-90.0 + np.cumsum(rng.normal(0, 2e-3, (b, n)), axis=1)).astype(np.float32)
    alt = rng.uniform(50, 12500, (b, n)).astype(np.float32)
    valid = (rng.uniform(size=(b, n)) < valid_frac).astype(np.float32)
    grid = np.linspace(0, t_span, m, dtype=np.float32)[None, :].repeat(b, axis=0)
    return t, lat, lon, alt, valid, grid


def assert_interp_matches(args):
    got = interp_tracks(*map(jnp.asarray, args))
    want = ref.interp_tracks_ref(*map(jnp.asarray, args))
    for name, g, w in zip(("lat", "lon", "alt", "vrate", "gspeed", "valid"), got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL,
                        err_msg=f"output {name}")


class TestInterpVsRef:
    def test_basic_batch(self):
        rng = np.random.default_rng(1)
        assert_interp_matches(make_track_batch(rng, 4, 32, 16))

    def test_aot_default_shapes(self):
        rng = np.random.default_rng(2)
        assert_interp_matches(make_track_batch(rng, 16, 128, 64))

    def test_all_valid(self):
        rng = np.random.default_rng(3)
        assert_interp_matches(make_track_batch(rng, 3, 16, 8, valid_frac=1.0))

    def test_no_valid_row_is_zero(self):
        rng = np.random.default_rng(4)
        t, lat, lon, alt, valid, grid = make_track_batch(rng, 2, 16, 8)
        valid[0, :] = 0.0
        out = interp_tracks(*map(jnp.asarray, (t, lat, lon, alt, valid, grid)))
        for arr in out:
            assert_allclose(np.asarray(arr)[0], 0.0, atol=1e-6)

    def test_single_valid_obs_row_is_zero(self):
        """<2 valid observations => valid=0 and zero outputs (paper drops
        short segments; kernel must still be total)."""
        rng = np.random.default_rng(5)
        t, lat, lon, alt, valid, grid = make_track_batch(rng, 2, 16, 8)
        valid[0, :] = 0.0
        valid[0, 3] = 1.0
        out = interp_tracks(*map(jnp.asarray, (t, lat, lon, alt, valid, grid)))
        assert np.asarray(out[5])[0].max() == 0.0
        assert_interp_matches((t, lat, lon, alt, valid, grid))

    def test_grid_outside_span_clamps_to_endpoints(self):
        t = np.array([[100.0, 200.0, 300.0]], dtype=np.float32)
        lat = np.array([[40.0, 41.0, 42.0]], dtype=np.float32)
        lon = np.array([[-71.0, -72.0, -73.0]], dtype=np.float32)
        alt = np.array([[1000.0, 2000.0, 3000.0]], dtype=np.float32)
        valid = np.ones((1, 3), dtype=np.float32)
        grid = np.array([[0.0, 150.0, 400.0]], dtype=np.float32)
        out = interp_tracks(*map(jnp.asarray, (t, lat, lon, alt, valid, grid)))
        o_alt = np.asarray(out[2])[0]
        assert o_alt[0] == pytest.approx(1000.0)   # before span -> first obs
        assert o_alt[1] == pytest.approx(1500.0)   # midpoint
        assert o_alt[2] == pytest.approx(3000.0)   # after span -> last obs

    def test_exact_hit_on_observation(self):
        t = np.array([[0.0, 10.0, 20.0, 30.0]], dtype=np.float32)
        lat = np.zeros((1, 4), dtype=np.float32)
        lon = np.zeros((1, 4), dtype=np.float32)
        alt = np.array([[100.0, 200.0, 300.0, 400.0]], dtype=np.float32)
        valid = np.ones((1, 4), dtype=np.float32)
        grid = np.array([[10.0, 20.0]], dtype=np.float32)
        out = interp_tracks(*map(jnp.asarray, (t, lat, lon, alt, valid, grid)))
        assert_allclose(np.asarray(out[2])[0], [200.0, 300.0], rtol=1e-5)

    def test_duplicate_timestamps_no_nan(self):
        t = np.array([[10.0, 10.0, 10.0, 20.0]], dtype=np.float32)
        lat = np.array([[40.0, 40.1, 40.2, 40.3]], dtype=np.float32)
        lon = np.full((1, 4), -71.0, dtype=np.float32)
        alt = np.array([[1000.0, 1100.0, 1200.0, 1300.0]], dtype=np.float32)
        valid = np.ones((1, 4), dtype=np.float32)
        grid = np.array([[5.0, 10.0, 15.0]], dtype=np.float32)
        out = interp_tracks(*map(jnp.asarray, (t, lat, lon, alt, valid, grid)))
        for arr in out:
            assert np.isfinite(np.asarray(arr)).all()
        assert_interp_matches((t, lat, lon, alt, valid, grid))

    def test_vertical_rate_of_constant_climb(self):
        """500 ft over 60 s of grid => 500 ft/min everywhere (uniform climb)."""
        n = 8
        t = np.linspace(0, 60, n, dtype=np.float32)[None, :]
        alt = (1000.0 + (500.0 / 60.0) * t).astype(np.float32)
        lat = np.full((1, n), 40.0, dtype=np.float32)
        lon = np.full((1, n), -71.0, dtype=np.float32)
        valid = np.ones((1, n), dtype=np.float32)
        grid = np.linspace(0, 60, 16, dtype=np.float32)[None, :]
        out = interp_tracks(*map(jnp.asarray, (t, lat, lon, alt, valid, grid)))
        assert_allclose(np.asarray(out[3])[0], 500.0, rtol=1e-3)

    def test_ground_speed_of_straight_northbound(self):
        """1 deg lat / 600 s = 60 nm / (1/6 h) = 360 kt."""
        n = 8
        t = np.linspace(0, 600, n, dtype=np.float32)[None, :]
        lat = (40.0 + t / 600.0).astype(np.float32)
        lon = np.full((1, n), -71.0, dtype=np.float32)
        alt = np.full((1, n), 3000.0, dtype=np.float32)
        valid = np.ones((1, n), dtype=np.float32)
        grid = np.linspace(0, 600, 16, dtype=np.float32)[None, :]
        out = interp_tracks(*map(jnp.asarray, (t, lat, lon, alt, valid, grid)))
        assert_allclose(np.asarray(out[4])[0], 360.0, rtol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 6),
        n=st.integers(4, 48),
        m=st.integers(3, 32),
        valid_frac=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, b, n, m, valid_frac, seed):
        rng = np.random.default_rng(seed)
        assert_interp_matches(make_track_batch(rng, b, n, m, valid_frac))


def make_agl_batch(rng, b, m, th=16, tw=16):
    lat = rng.uniform(41.0, 41.9, (b, m)).astype(np.float32)
    lon = rng.uniform(-72.0, -71.1, (b, m)).astype(np.float32)
    alt = rng.uniform(500, 12500, (b, m)).astype(np.float32)
    dem = rng.uniform(0, 800, (th, tw)).astype(np.float32)
    meta = np.array([41.0, -72.0, 1.0 / th, 1.0 / tw], dtype=np.float32)
    return lat, lon, alt, dem, meta


def assert_agl_matches(args):
    got = agl_tracks(*map(jnp.asarray, args))
    want = ref.agl_tracks_ref(*map(jnp.asarray, args))
    for name, g, w in zip(("agl", "elev"), got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL,
                        err_msg=f"output {name}")


class TestAglVsRef:
    def test_basic_batch(self):
        rng = np.random.default_rng(11)
        assert_agl_matches(make_agl_batch(rng, 4, 16))

    def test_aot_default_shapes(self):
        rng = np.random.default_rng(12)
        assert_agl_matches(make_agl_batch(rng, 16, 64, 64, 64))

    def test_exact_on_lattice_points(self):
        """Queries exactly on DEM lattice points return the cell value."""
        th = tw = 8
        dem = np.arange(th * tw, dtype=np.float32).reshape(th, tw)
        meta = np.array([40.0, -80.0, 0.5, 0.5], dtype=np.float32)
        lat = np.array([[40.0, 40.5, 43.5]], dtype=np.float32)  # rows 0,1,7
        lon = np.array([[-80.0, -79.5, -76.5]], dtype=np.float32)  # cols 0,1,7
        alt = np.zeros((1, 3), dtype=np.float32)
        agl, elev = agl_tracks(*map(jnp.asarray, (lat, lon, alt, dem, meta)))
        expect = np.array([dem[0, 0], dem[1, 1], dem[7, 7]]) * ref.FT_PER_M
        assert_allclose(np.asarray(elev)[0], expect, rtol=1e-5)
        assert_allclose(np.asarray(agl)[0], -expect, rtol=1e-5)

    def test_border_clamp_outside_tile(self):
        th = tw = 4
        dem = np.ones((th, tw), dtype=np.float32) * 100.0
        dem[0, 0] = 7.0
        meta = np.array([40.0, -80.0, 0.1, 0.1], dtype=np.float32)
        lat = np.array([[0.0]], dtype=np.float32)    # far south of tile
        lon = np.array([[-179.0]], dtype=np.float32)  # far west of tile
        alt = np.array([[1000.0]], dtype=np.float32)
        agl, elev = agl_tracks(*map(jnp.asarray, (lat, lon, alt, dem, meta)))
        assert_allclose(np.asarray(elev)[0, 0], 7.0 * ref.FT_PER_M, rtol=1e-5)

    def test_flat_terrain_agl_is_alt_minus_const(self):
        rng = np.random.default_rng(13)
        lat, lon, alt, dem, meta = make_agl_batch(rng, 2, 8)
        dem[:] = 100.0
        agl, elev = agl_tracks(*map(jnp.asarray, (lat, lon, alt, dem, meta)))
        assert_allclose(np.asarray(agl), alt - 100.0 * ref.FT_PER_M, rtol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 5),
        m=st.integers(1, 24),
        th=st.integers(2, 24),
        tw=st.integers(2, 24),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, b, m, th, tw, seed):
        rng = np.random.default_rng(seed)
        assert_agl_matches(make_agl_batch(rng, b, m, th, tw))
