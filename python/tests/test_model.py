"""L2 correctness: full track model (pallas path) vs oracle + invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile import model as model_mod
from compile.kernels import ref
from tests.test_kernels import make_track_batch

RTOL = 1e-4
ATOL = 1e-3


def make_model_batch(rng, b=4, n=32, m=16, tile=16):
    t, lat, lon, alt, valid, grid = make_track_batch(rng, b, n, m)
    # DEM tile covering the track region with margin.
    dem = rng.uniform(0, 600, (tile, tile)).astype(np.float32)
    meta = np.array([39.0, -91.0, 4.0 / tile, 4.0 / tile], dtype=np.float32)
    return t, lat, lon, alt, valid, grid, dem, meta


class TestTrackModel:
    def test_matches_oracle(self):
        rng = np.random.default_rng(21)
        args = make_model_batch(rng)
        got = model_mod.track_model(*map(jnp.asarray, args))
        want = model_mod.track_model_ref(*map(jnp.asarray, args))
        for name, g, w in zip(model_mod.OUTPUT_NAMES, got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL,
                            err_msg=f"output {name}")

    def test_aot_default_shapes_match_oracle(self):
        rng = np.random.default_rng(22)
        args = make_model_batch(
            rng, model_mod.DEFAULT_B, model_mod.DEFAULT_N,
            model_mod.DEFAULT_M, model_mod.DEFAULT_TILE,
        )
        got = model_mod.track_model(*map(jnp.asarray, args))
        want = model_mod.track_model_ref(*map(jnp.asarray, args))
        for name, g, w in zip(model_mod.OUTPUT_NAMES, got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL,
                            err_msg=f"output {name}")

    def test_output_count_and_shapes(self):
        rng = np.random.default_rng(23)
        args = make_model_batch(rng, b=3, n=16, m=8, tile=8)
        out = model_mod.track_model(*map(jnp.asarray, args))
        assert len(out) == len(model_mod.OUTPUT_NAMES)
        for arr in out:
            assert arr.shape == (3, 8)
            assert arr.dtype == jnp.float32

    def test_agl_equals_alt_minus_elev_when_valid(self):
        rng = np.random.default_rng(24)
        args = make_model_batch(rng)
        lat, lon, alt, vrate, gspeed, agl, valid = (
            np.asarray(a) for a in model_mod.track_model(*map(jnp.asarray, args))
        )
        _, elev = ref.agl_tracks_ref(
            jnp.asarray(lat), jnp.asarray(lon), jnp.asarray(alt),
            jnp.asarray(args[6]), jnp.asarray(args[7]),
        )
        mask = valid > 0.5
        assert_allclose(agl[mask], (alt - np.asarray(elev))[mask], rtol=1e-4, atol=1e-2)

    def test_all_finite(self):
        rng = np.random.default_rng(25)
        for _ in range(3):
            args = make_model_batch(rng)
            for arr in model_mod.track_model(*map(jnp.asarray, args)):
                assert np.isfinite(np.asarray(arr)).all()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 4),
           n=st.integers(4, 32), m=st.integers(3, 16), tile=st.integers(2, 16))
    def test_hypothesis_model_sweep(self, seed, b, n, m, tile):
        rng = np.random.default_rng(seed)
        args = make_model_batch(rng, b, n, m, tile)
        got = model_mod.track_model(*map(jnp.asarray, args))
        want = model_mod.track_model_ref(*map(jnp.asarray, args))
        for name, g, w in zip(model_mod.OUTPUT_NAMES, got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL,
                            err_msg=f"output {name}")
